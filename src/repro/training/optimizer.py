"""AdamW with sharded, dtype-configurable state (ZeRO-style: optimizer state
inherits each parameter's sharding, so m/v are fully distributed).

``state_dtype="bfloat16"`` halves optimizer HBM (used for grok-314B to fit
16 GB/chip — see EXPERIMENTS.md); fp32 is the default.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * scale


def init_opt_state(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, opt_state, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step.astype(jnp.float32))
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m32 = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * gf
        v32 = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * gf * gf
        mhat = m32 / (1 - cfg.beta1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.beta2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
