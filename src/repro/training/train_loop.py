"""Training step builder: loss + grad + optimizer update (+ optional gradient
accumulation), pure and jit/pjit-friendly. ``TrainState`` is the checkpoint
unit."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import LM
from repro.models import layers as L
from repro.training import optimizer as O


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    rng: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.opt_state, self.rng), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(model: LM, opt_cfg: O.OptimizerConfig, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params,
                      opt_state=O.init_opt_state(params, opt_cfg),
                      rng=jax.random.key_data(jax.random.key(0)))


def make_train_step(model: LM, opt_cfg: O.OptimizerConfig, *,
                    kernels=L.DEFAULT_KERNELS,
                    accum_steps: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    With ``accum_steps > 1``, the batch's leading dim is split into
    microbatches accumulated with a ``lax.scan`` (memory-bounded)."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, kernels=kernels)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss, "aux": jnp.zeros(())}

        new_params, new_opt, opt_metrics = O.apply_updates(
            state.params, grads, state.opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               rng=state.rng)
        return new_state, metrics

    return train_step
