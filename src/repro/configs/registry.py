"""Architecture registry: the 10 assigned architectures (exact published
configs), the paper's 6 benchmark models, reduced smoke-test variants, and
``input_specs()`` producing ShapeDtypeStruct stand-ins for the dry-run."""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

ARCH_IDS = [
    "hymba_1p5b", "qwen1p5_110b", "codeqwen1p5_7b", "nemotron4_15b",
    "qwen3_4b", "grok1_314b", "deepseek_v2_lite_16b", "hubert_xlarge",
    "falcon_mamba_7b", "qwen2_vl_7b",
]

# paper's six evaluation models (Figs. 2-3, Tables I-II)
PAPER_MODEL_IDS = [
    "qwen1p5_4b_chat", "qwen1p5_1p8b_chat", "llama_13b", "codellama_7b",
    "llama2_7b", "llama3_8b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: small widths/layers/experts, tiny vocab."""
    cfg = get_config(arch)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads) or heads
    upd = dict(
        num_layers=min(cfg.num_layers, 4 if not cfg.global_attn_layers else 5),
        d_model=128, num_heads=heads, num_kv_heads=kv, head_dim=32,
        d_ff=256 if cfg.d_ff else 0, vocab_size=512,
        dtype="float32", remat="none",
    )
    if cfg.num_experts:
        # capacity_factor = E guarantees cap >= topk*T: no token drops, so
        # decode and full-forward are bit-comparable in tests
        upd.update(num_experts=4,
                   num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
                   moe_d_ff=64, capacity_factor=4.0)
    if cfg.attn_type == "mla":
        upd.update(kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16,
                   v_head_dim=32)
    if cfg.global_attn_layers:
        upd.update(global_attn_layers=(0, 2, 4), sliding_window=16)
    if cfg.meta_tokens:
        upd.update(meta_tokens=8)
    if cfg.mrope_sections:
        upd.update(mrope_sections=(4, 6, 6))   # sums to head_dim//2 = 16
    return dataclasses.replace(cfg, **upd)


# ------------------------------------------------------------------ input specs
def _sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Shape-skip rules (DESIGN.md §4)."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "pure full-attention arch: 500k dense KV excluded (sub-quadratic required)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train  : tokens + labels (+frontend embeds)
    prefill: tokens (engine provides cache separately)
    decode : one new token per sequence
    """
    b = batch_override or shape.global_batch
    s = shape.seq_len
    act_dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {"tokens": _sds((b, s)), "labels": _sds((b, s))}
    elif shape.kind == "prefill":
        specs = {"tokens": _sds((b, s))}
    else:  # decode: one new token, cache of length seq_len handled by caller
        specs = {"tokens": _sds((b, 1))}

    toks = specs["tokens"].shape[1]
    if cfg.frontend == "audio":
        # HuBERT stub frontend: precomputed frame embeddings replace tokens
        specs["input_embeds"] = _sds((b, toks, cfg.d_model), act_dtype)
        if shape.kind == "train":
            specs["loss_mask"] = _sds((b, s), jnp.float32)
    elif cfg.frontend == "vision" and shape.kind != "decode":
        # qwen2-vl stub: patch embeddings spliced where embed_mask is set
        specs["input_embeds"] = _sds((b, toks, cfg.d_model), act_dtype)
        specs["embed_mask"] = _sds((b, toks), jnp.bool_)
    if cfg.mrope_sections:
        nmeta = cfg.meta_tokens if shape.kind != "decode" else 0
        specs["positions"] = _sds((3, b, toks + nmeta))
    return specs
