"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: MLA (kv_lora=512) + MoE 64 routed
top-6 + 2 shared experts, first layer dense. (The assignment note's "160
routed" is full DeepSeek-V2's count; the primary spec "64e top-6" is
V2-Lite's published config and is used here — see DESIGN.md.)"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=192, d_ff=10944, vocab_size=102400,
    attn_type="mla", kv_lora_rank=512, qk_nope_head_dim=128,
    qk_rope_head_dim=64, v_head_dim=128,
    num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1408, first_dense_layers=1, rope_theta=10_000.0,
    moe_impl="ep",      # shard_map expert-parallel (EXPERIMENTS.md §Perf cell A)
)
