"""Hymba-1.5B [arXiv:2411.13676]: hybrid parallel attention+mamba heads,
SWA(1024) with 3 global-attention layers, 128 meta tokens (attention sinks),
GQA kv=5. ssm_state=16."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    sliding_window=1024, global_attn_layers=(0, 15, 31),
    meta_tokens=128, ssm_state=16, ssm_conv=4, ssm_expand=2,
)
