"""Qwen2-VL-7B [arXiv:2409.12191]: GQA kv=4 with M-RoPE (t/h/w sections),
dynamic-resolution vision frontend STUBBED (input_specs provides patch
embeddings + splice mask)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    head_dim=128, d_ff=18944, vocab_size=152064,
    qkv_bias=True, frontend="vision", mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
)
