"""Nemotron-4-15B [arXiv:2402.16819]: GQA kv=8, squared-ReLU MLP, LayerNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=256000,
    act="sq_relu", norm_type="layernorm", rope_theta=10_000.0,
)
