"""Grok-1 314B [hf:xai-org/grok-1]: MoE 8 experts top-2, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=32768, vocab_size=131072,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=32768,
    rope_theta=10_000.0,
    attn_q_chunk=512,   # see qwen1p5_110b note
    moe_impl="ep",      # shard_map expert-parallel (EXPERIMENTS.md §Perf cell A)
)
