"""Model / run configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention options
    attn_type: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0          # 0 -> full attention
    global_attn_layers: tuple[int, ...] = ()   # layers exempt from SWA
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE

    # MLA (deepseek)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # norm / activation
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | sq_relu
    norm_eps: float = 1e-6

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # leading layers with dense FFN
    router_aux_loss: float = 0.01
    capacity_factor: float = 1.25
    # dispatch locality groups: tokens rank/scatter within each group so the
    # scatter never crosses data-parallel shards (set = dp shards at launch;
    # 1 = global dispatch). See models/ffn.py and EXPERIMENTS.md §Perf.
    moe_dispatch_groups: int = 1
    # "einsum": GSPMD-auto dispatch (baseline). "ep": shard_map expert
    # parallelism — per-shard dispatch buckets exchanged with all_to_all over
    # the model axis (EXPERIMENTS.md §Perf cell A).
    moe_impl: str = "einsum"

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)

    # hybrid (hymba)
    meta_tokens: int = 0

    # embedding / head
    tie_embeddings: bool = False
    is_encoder: bool = False         # encoder-only (no causal mask, no decode)
    frontend: str | None = None      # None | "audio" | "vision" (stub embeddings)

    # attention memory tiling (query rows per logits block; see models/attention.py)
    attn_q_chunk: int = 2048

    # numerics
    dtype: Any = "bfloat16"
    remat: str = "full"              # none | full | dots (activation ckpt policy)
    scan_layers: bool = True         # lax.scan over layers (O(1) HLO)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.dt_rank == 0 and self.family in ("ssm", "hybrid"):
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def moe_layers(self) -> int:
        return self.num_layers - self.first_dense_layers if self.num_experts else 0

    @property
    def uses_attention(self) -> bool:
        return self.attn_type != "none"

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6 N D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts only)."""
        return _param_count(self, active_only=True)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    if cfg.act == "swiglu":
        return 3 * cfg.d_model * d_ff
    return 2 * cfg.d_model * d_ff          # sq_relu: up + down


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.attn_type == "mla":
        q = d * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        kv_a = d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        kv_b = cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
        o = cfg.num_heads * cfg.v_head_dim * d
        return q + kv_a + kv_b + o
    if cfg.attn_type == "none":
        return 0
    qkv = d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
    return qkv + cfg.num_heads * hd * d


def _ssm_params(cfg: ModelConfig) -> int:
    di, s, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return (cfg.d_model * 2 * di            # in_proj (x, z)
            + di * cfg.ssm_conv             # depthwise conv
            + di * (dr + 2 * s)             # x_proj
            + dr * di + di                  # dt_proj
            + di * s + di                   # A_log, D
            + di * cfg.d_model)             # out_proj


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    per_layer_attn = _attn_params(cfg) if cfg.uses_attention else 0
    if cfg.family == "ssm":
        per_layer = _ssm_params(cfg)
    elif cfg.family == "hybrid":
        per_layer = per_layer_attn + _ssm_params(cfg) + _ffn_params(cfg, cfg.d_ff)
    elif cfg.num_experts:
        experts = cfg.num_experts_per_tok if active_only else cfg.num_experts
        moe = (experts + cfg.num_shared_experts) * _ffn_params(cfg, cfg.moe_d_ff)
        moe += cfg.d_model * cfg.num_experts      # router
        per_layer = per_layer_attn + moe
    else:
        per_layer = per_layer_attn + _ffn_params(cfg, cfg.d_ff)

    total = cfg.num_layers * per_layer
    if cfg.num_experts and cfg.first_dense_layers:
        dense_ffn = _ffn_params(cfg, cfg.d_ff)
        experts = cfg.num_experts_per_tok if active_only else cfg.num_experts
        moe = ((experts + cfg.num_shared_experts) * _ffn_params(cfg, cfg.moe_d_ff)
               + cfg.d_model * cfg.num_experts)
        total += cfg.first_dense_layers * (dense_ffn - moe)
    total += cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
