from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import (ARCH_IDS, PAPER_MODEL_IDS, applicable,
                                    get_config, input_specs, smoke_config)
