"""The paper's six evaluation models (vLLM + GPTQ-int4 on the HYGON DCU;
Figs. 2-3, Tables I-II), as exact published configs for the benchmark harness."""
from repro.configs.base import ModelConfig

QWEN1P5_4B_CHAT = ModelConfig(
    name="qwen1.5-4b-chat-gptq-int4", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    head_dim=128, d_ff=6912, vocab_size=151936, qkv_bias=True,
    rope_theta=5_000_000.0,
)
QWEN1P5_1P8B_CHAT = ModelConfig(
    name="qwen1.5-1.8b-chat-gptq-int4", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=5504, vocab_size=151936, qkv_bias=True,
    rope_theta=1_000_000.0,
)
LLAMA_13B = ModelConfig(
    name="llama-13b-gptq", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=40,
    head_dim=128, d_ff=13824, vocab_size=32000,
)
CODELLAMA_7B = ModelConfig(
    name="codellama-7b-gptq", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    head_dim=128, d_ff=11008, vocab_size=32016, rope_theta=1_000_000.0,
)
LLAMA2_7B = ModelConfig(
    name="llama-2-7b-gptq", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    head_dim=128, d_ff=11008, vocab_size=32000,
)
LLAMA3_8B = ModelConfig(
    name="meta-llama-3-8b-gptq", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=128256, rope_theta=500_000.0,
)

PAPER_MODELS = {
    "qwen1p5_4b_chat": QWEN1P5_4B_CHAT,
    "qwen1p5_1p8b_chat": QWEN1P5_1P8B_CHAT,
    "llama_13b": LLAMA_13B,
    "codellama_7b": CODELLAMA_7B,
    "llama2_7b": LLAMA2_7B,
    "llama3_8b": LLAMA3_8B,
}
# display order used in the paper's figures
PAPER_ORDER = ["qwen1p5_4b_chat", "qwen1p5_1p8b_chat", "llama_13b",
               "codellama_7b", "llama2_7b", "llama3_8b"]
