"""Fault tolerance + elasticity for the training loop.

* ``resilient_train_loop`` — checkpoint/restart: periodic async checkpoints,
  resume from the latest committed step, deterministic data replay (the
  pipeline is seekable so a restart consumes exactly the remaining batches).
  Optional failure injection for tests (process-level kill simulation).
* ``elastic_restore`` — restore a checkpoint onto a *different* mesh: leaves
  are host arrays; re-sharding happens at device_put with the new shardings
  (elastic scale-up/down between jobs).
* ``Heartbeat`` — wall-clock watchdog: at real scale this is the hook that
  detects stalled steps (straggler / dead host) and triggers job restart; here
  it powers the straggler-mitigation test.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable


from repro.checkpoint.checkpointer import Checkpointer


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class Heartbeat:
    """Wall-clock watchdog shared by the training loop and the serving
    worker (DESIGN.md §14).

    The worker thread calls ``beat()`` each iteration; a *separate* monitor
    thread calls ``check()``.  Staleness must be detected from the monitor
    side: the old design only bumped ``missed`` inside ``beat()``, so a
    worker that stopped beating — the exact failure a watchdog exists for —
    was never counted as missed.  ``check()`` charges one missed beat per
    elapsed ``timeout_s`` window since the last beat, however the worker is
    (mis)behaving.

    ``clock`` is injectable (``serving/clock.py``) so stall tests advance
    time manually instead of sleeping.
    """
    timeout_s: float = 300.0
    clock: Callable[[], float] = time.time
    last_beat: float | None = None
    missed: int = 0
    # how much of the current staleness check() has already charged
    _charged: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self):
        if self.last_beat is None:
            self.last_beat = self.clock()

    def beat(self):
        now = self.clock()
        if now - self.last_beat > self.timeout_s and not self._charged:
            # late beat that no monitor observed — still a missed window
            self.missed += 1
        self.last_beat = now
        self._charged = 0

    def check(self) -> bool:
        """Monitor-side probe: charge newly-elapsed missed windows and
        return whether the worker is currently healthy."""
        windows = int((self.clock() - self.last_beat) // self.timeout_s)
        if windows > self._charged:
            self.missed += windows - self._charged
            self._charged = windows
        return windows == 0

    @property
    def stale_s(self) -> float:
        """Seconds since the last beat, as seen by the monitor."""
        return self.clock() - self.last_beat

    @property
    def healthy(self) -> bool:
        return self.clock() - self.last_beat <= self.timeout_s


def resilient_train_loop(train_step: Callable, init_state: Any, pipeline,
                         *, steps: int, ckpt: Checkpointer,
                         ckpt_every: int = 10, async_ckpt: bool = True,
                         fail_at_step: int | None = None,
                         to_batch=None) -> tuple[Any, list[dict], int]:
    """Runs [resume_step, steps). Returns (state, metrics_log, start_step).

    On entry, resumes from the latest committed checkpoint if present —
    calling this again after a crash continues where the last commit left off.
    ``fail_at_step`` raises InjectedFailure AFTER that step's optimizer update
    but BEFORE its checkpoint would commit (the nastiest crash point).
    """
    start_step = 0
    state = init_state
    latest = ckpt.latest_step()
    if latest is not None:
        state, extra = ckpt.restore(init_state)
        start_step = int(extra.get("next_step", latest + 1))

    log: list[dict] = []
    for step in range(start_step, steps):
        batch = pipeline.batch_at(step)
        if to_batch is not None:
            batch = to_batch(batch)
        state, metrics = train_step(state, batch)
        log.append({"step": step,
                    **{k: float(v) for k, v in metrics.items()}})
        if fail_at_step is not None and step == fail_at_step:
            raise InjectedFailure(f"injected failure at step {step}")
        if (step + 1) % ckpt_every == 0 or step == steps - 1:
            ckpt.save(step, state, blocking=not async_ckpt,
                      extra={"next_step": step + 1})
    ckpt.wait()
    return state, log, start_step


def elastic_restore(ckpt: Checkpointer, template: Any, shardings: Any,
                    step: int | None = None):
    """Restore onto (possibly different) mesh shardings."""
    return ckpt.restore(template, step=step, shardings=shardings)
