"""Deterministic, shardable, resumable synthetic data pipelines.

* ``LMDataPipeline`` — tokenized LM batches (train substrate): deterministic
  per-step RNG (resume = seek), host-sharded (each data-parallel host draws
  only its rows), Zipf-ish token marginals so losses are non-degenerate.
* ``sharegpt_stream`` — ShareGPT-like request stream for throughput benches
  (the paper's workload): lognormal prompt/output lengths, Poisson arrivals.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class LMDataPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.local_batch = self.global_batch // self.host_count

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a given step (resume-safe: a restarted job
        re-requests exactly the batches it would have seen)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_index)
        zipf = rng.zipf(1.3, size=(self.local_batch, self.seq_len + 1))
        tokens = np.minimum(zipf, self.vocab_size - 1).astype(np.int32)
        return {"tokens": tokens[:, :-1],
                "labels": tokens[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class SyntheticRequest:
    arrival_s: float
    prompt_len: int
    output_len: int
    prompt: list[int]


def sharegpt_stream(n_requests: int, *, vocab_size: int, seed: int = 0,
                    mean_prompt: float = 32.0, mean_output: float = 16.0,
                    qps: float = 8.0, max_prompt: int = 1024) -> list[SyntheticRequest]:
    """ShareGPT_V3-like synthetic workload: lognormal lengths (heavy tail),
    Poisson arrivals — the statistics the paper's throughput runs sample."""
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / qps, size=n_requests))
    pl = np.clip(rng.lognormal(np.log(mean_prompt), 0.7, n_requests), 1,
                 max_prompt).astype(int)
    ol = np.clip(rng.lognormal(np.log(mean_output), 0.6, n_requests), 1,
                 4 * mean_output).astype(int)
    return [SyntheticRequest(
        arrival_s=float(arr[i]), prompt_len=int(pl[i]), output_len=int(ol[i]),
        prompt=rng.integers(2, vocab_size, size=int(pl[i])).tolist())
        for i in range(n_requests)]
