"""Pallas TPU kernel for fused GPTQ W4A16 matmul — the paper's kernel.

y[M, N] = x[M, K] @ dequant(qweight[K//8, N], scales[G, N], qzeros[G, N//8])

Strategy flags (core/opt_strategies.py) select the paper's ablation variants:

* SMB  (``accum_vmem``): fp32 VMEM scratch accumulator, K-innermost grid,
  single writeback on the last K step — vs. K-OUTERMOST grid where every K
  step revisits the HBM-backed output block (read-modify-write), the TPU
  analogue of the DCU baseline's per-thread global atomicAdd traffic.
* VML  (``packed_loads``): weights arrive as packed int32 (8 nibbles/word,
  K/8 rows) and are unpacked with vector shifts in VREGs — vs. a pre-expanded
  int8 array with 2x the HBM footprint.
* ILA  (``mxu``): the dequantized (bk, bn) tile feeds the MXU via ``jnp.dot``
  (f32 accumulation) — vs. a VPU fori-loop of broadcast multiply+add
  (the compiler-generated-scalar-code analogue).

Tiling: blocks are (8,128)-aligned; defaults bm=128, bn=256, bk=512 give a
~0.33 MB working set (see DESIGN.md §6).  Requested block sizes are legalized
for the actual shape (``resolve_block_sizes``: bk shrinks to divide K and
align with the group size; N is zero-padded up to a multiple of bn) — a
``ValueError`` is raised only for shapes the packed layout cannot serve.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.opt_strategies import KernelStrategy, OPT4GPTQ

NIB = packing.NIBBLES_PER_WORD


def _unpack_rows_block(qw, bk):
    """(bk//8, bn) int32 -> (bk, bn) f32 nibble values, vector shift/mask."""
    q = qw.astype(jnp.uint32)
    shifts = (4 * jnp.arange(NIB, dtype=jnp.uint32))[None, :, None]
    nib = (q[:, None, :] >> shifts) & jnp.uint32(0xF)
    return nib.reshape(bk, q.shape[-1]).astype(jnp.float32)


def _unpack_cols_block(qz, bn):
    """(gk, bn//8) int32 -> (gk, bn) f32 zero points."""
    q = qz.astype(jnp.uint32)
    shifts = (4 * jnp.arange(NIB, dtype=jnp.uint32))[None, None, :]
    nib = (q[:, :, None] >> shifts) & jnp.uint32(0xF)
    return nib.reshape(q.shape[0], bn).astype(jnp.float32)


def _dequant_tile(w_nib, s, z, bk, group_size):
    """(bk, bn) nibbles + (gk, bn) scales/zeros -> (bk, bn) dequantized f32."""
    gk = s.shape[0]
    if gk == 1:
        return (w_nib - z) * s                       # broadcast over rows
    reps = bk // gk
    s_rep = jnp.repeat(s, reps, axis=0)
    z_rep = jnp.repeat(z, reps, axis=0)
    return (w_nib - z_rep) * s_rep


def _compute_tile(x_tile, w_tile, mxu: bool):
    """x:(bm,bk) f32  w:(bk,bn) f32 -> (bm,bn) f32 partial product."""
    if mxu:
        return jnp.dot(x_tile, w_tile, preferred_element_type=jnp.float32)
    # ILA-off: VPU broadcast multiply + add, one K row per step.
    bm, bk = x_tile.shape
    bn = w_tile.shape[1]

    def body(j, acc):
        xj = jax.lax.dynamic_slice_in_dim(x_tile, j, 1, axis=1)       # (bm, 1)
        wj = jax.lax.dynamic_slice_in_dim(w_tile, j, 1, axis=0)       # (1, bn)
        return acc + xj * wj

    return jax.lax.fori_loop(0, bk, body, jnp.zeros((bm, bn), jnp.float32))


# --------------------------------------------------------------------- kernels
def _kernel_vmem(x_ref, qw_ref, s_ref, qz_ref, o_ref, acc_ref, *,
                 bk, group_size, strategy: KernelStrategy):
    """K-innermost grid; fp32 VMEM accumulator; single writeback (SMB on)."""
    knum = pl.num_programs(2)
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if strategy.packed_loads:
        w_nib = _unpack_rows_block(qw_ref[...], bk)
    else:
        w_nib = qw_ref[...].astype(jnp.float32)
    z = _unpack_cols_block(qz_ref[...], s_ref.shape[1])
    w = _dequant_tile(w_nib, s_ref[...].astype(jnp.float32), z, bk, group_size)
    acc_ref[...] += _compute_tile(x_ref[...].astype(jnp.float32), w, strategy.mxu)

    @pl.when(kidx == knum - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_hbm(x_ref, qw_ref, s_ref, qz_ref, o_ref, *,
                bk, group_size, strategy: KernelStrategy):
    """K-OUTERMOST grid; output block revisited (evict+reload through HBM each
    K sweep) — the global-memory atomic-accumulation analogue (SMB off)."""
    kidx = pl.program_id(0)

    @pl.when(kidx == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    if strategy.packed_loads:
        w_nib = _unpack_rows_block(qw_ref[...], bk)
    else:
        w_nib = qw_ref[...].astype(jnp.float32)
    z = _unpack_cols_block(qz_ref[...], s_ref.shape[1])
    w = _dequant_tile(w_nib, s_ref[...].astype(jnp.float32), z, bk, group_size)
    part = _compute_tile(x_ref[...].astype(jnp.float32), w, strategy.mxu)
    o_ref[...] += part.astype(o_ref.dtype)


def _kernel_dequant(qw_ref, s_ref, qz_ref, w_ref, *, bk, group_size, packed):
    """Pass 1 of the 'naive' strategy: materialize bf16 weights to HBM."""
    if packed:
        w_nib = _unpack_rows_block(qw_ref[...], bk)
    else:
        w_nib = qw_ref[...].astype(jnp.float32)
    z = _unpack_cols_block(qz_ref[...], s_ref.shape[1])
    w = _dequant_tile(w_nib, s_ref[...].astype(jnp.float32), z, bk, group_size)
    w_ref[...] = w.astype(w_ref.dtype)


def _kernel_matmul(x_ref, w_ref, o_ref, acc_ref):
    """Pass 2 of the 'naive' strategy: plain bf16 matmul (re-reads W from HBM)."""
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(kidx == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# ------------------------------------------------------------------ dispatcher
def _scale_block(bk, group_size):
    """Rows of the scales/zeros block covering bk K-rows."""
    return max(bk // group_size, 1)


def resolve_block_sizes(m: int, k: int, n: int, group_size: int,
                        bm: int, bn: int, bk: int) -> tuple[int, int, int]:
    """Shrink requested blocks to legal sizes for this shape.

    Legal means: bm/bn/bk multiples of 8 (packed rows come in 8-nibble words),
    bk divides K and aligns with the quantization group (bk % g == 0 or
    g % bk == 0).  N never constrains bn — the caller pads N up to a multiple
    of bn (see ``pad_cols``).  Raises ``ValueError`` only when no legal K
    block exists (K not servable by the packed layout).
    """
    g = group_size if group_size > 0 else k
    if k % NIB != 0:
        raise ValueError(
            f"K={k} not divisible by {NIB}: unservable by int4 row packing "
            f"(shape M={m}, K={k}, N={n}, group_size={group_size})")
    bm = max(min(_round_up(bm, 8), _round_up(m, 8)), 8)
    bn = max(min(_round_up(bn, 8), _round_up(n, 8)), 8)
    bk_req = max(min(bk, k) // NIB * NIB, NIB)
    bk = None
    for cand in range(bk_req, 0, -NIB):
        if k % cand == 0 and (cand % g == 0 or g % cand == 0):
            bk = cand
            break
    if bk is None:
        raise ValueError(
            f"no legal K block for M={m}, K={k}, N={n}, "
            f"group_size={group_size}: need a multiple of {NIB} that divides "
            f"K and aligns with the group size")
    return bm, bn, bk


def pad_cols(qweight: jnp.ndarray, scales: jnp.ndarray, qzeros: jnp.ndarray,
             n: int, bn: int):
    """Zero-pad the N axis up to a multiple of bn so any (8,128)-aligned bn is
    servable (e.g. N=1000 with bn=256 pads to 1024; output is sliced back).
    Padded columns dequantize to (0 - 0) * 1 = 0 and never reach the caller."""
    if n % NIB != 0:
        raise ValueError(f"N={n} not divisible by {NIB}: unservable by int4 "
                         f"column packing of qzeros")
    n_pad = _round_up(n, bn)
    if n_pad == n:
        return qweight, scales, qzeros, n
    dn = n_pad - n
    qweight = jnp.pad(qweight, ((0, 0), (0, dn)))
    scales = jnp.pad(scales, ((0, 0), (0, dn)), constant_values=1.0)
    qzeros = jnp.pad(qzeros, ((0, 0), (0, dn // NIB)))
    return qweight, scales, qzeros, n_pad


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "strategy", "bm", "bn", "bk", "out_dtype",
                     "interpret"))
def gptq_matmul(x: jnp.ndarray, qweight: jnp.ndarray, scales: jnp.ndarray,
                qzeros: jnp.ndarray, *, group_size: int,
                strategy: KernelStrategy = OPT4GPTQ,
                bm: int = 128, bn: int = 256, bk: int = 512,
                out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    """Fused GPTQ matmul. x: (M, K). qweight: (K//8, N) int32 when
    ``strategy.packed_loads`` else (K, N) int8 (pre-expanded). Caller applies
    the act-order permutation to x (see ops.gptq_linear)."""
    m, k = x.shape
    n = scales.shape[1]
    g = group_size if group_size > 0 else k
    bm, bn, bk = resolve_block_sizes(m, k, n, group_size, bm, bn, bk)
    qweight, scales, qzeros, n_pad = pad_cols(qweight, scales, qzeros, n, bn)
    gk = _scale_block(bk, g)
    # scales/qzeros row *block* index for K-step ki: BlockSpec index maps
    # count in blocks of gk rows, so the group-row element offset ki*bk//g
    # must be divided by the block height — ki when bk >= g (each K block
    # owns its own gk group rows), ki*bk//g when bk < g (several K blocks
    # share one group row).  The previous ki*bk//g element-offset form read
    # the wrong group rows whenever gk > 1 and K spanned > 2 blocks
    # (interpret-mode index clamping masked it at 2).
    sdiv = g * gk

    def _s_inner(mi, ni, ki):
        return (ki * bk // sdiv, ni)

    def _s_outer(ki, mi, ni):
        return (ki * bk // sdiv, ni)

    m_pad = _round_up(m, bm)
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    nm, nn, nk = m_pad // bm, n_pad // bn, k // bk
    out_dtype = out_dtype or x.dtype
    out_shape = jax.ShapeDtypeStruct((m_pad, n_pad), out_dtype)

    if strategy.packed_loads:
        qw_spec_inner = pl.BlockSpec((bk // NIB, bn), lambda mi, ni, ki: (ki, ni))
        qw_spec_outer = pl.BlockSpec((bk // NIB, bn), lambda ki, mi, ni: (ki, ni))
    else:
        qw_spec_inner = pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni))
        qw_spec_outer = pl.BlockSpec((bk, bn), lambda ki, mi, ni: (ki, ni))

    if not strategy.fused:
        # naive two-pass: dequant whole W to HBM, then matmul re-reads it.
        w_bf16 = pl.pallas_call(
            functools.partial(_kernel_dequant, bk=bk, group_size=g,
                              packed=strategy.packed_loads),
            grid=(nk, nn),
            in_specs=[
                pl.BlockSpec((bk // NIB, bn) if strategy.packed_loads else (bk, bn),
                             lambda ki, ni: (ki, ni)),
                pl.BlockSpec((gk, bn), lambda ki, ni: (ki * bk // sdiv, ni)),
                pl.BlockSpec((gk, bn // NIB),
                             lambda ki, ni: (ki * bk // sdiv, ni)),
            ],
            out_specs=pl.BlockSpec((bk, bn), lambda ki, ni: (ki, ni)),
            out_shape=jax.ShapeDtypeStruct((k, n_pad), jnp.bfloat16),
            interpret=interpret,
        )(qweight, scales, qzeros)
        y = pl.pallas_call(
            _kernel_matmul,
            grid=(nm, nn, nk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
                pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(x, w_bf16)
        return y[:m, :n]

    if strategy.accum_vmem:
        y = pl.pallas_call(
            functools.partial(_kernel_vmem, bk=bk, group_size=g,
                              strategy=strategy),
            grid=(nm, nn, nk),                      # K innermost
            in_specs=[
                pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
                qw_spec_inner,
                pl.BlockSpec((gk, bn), _s_inner),
                pl.BlockSpec((gk, bn // NIB), _s_inner),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(x, qweight, scales, qzeros)
    else:
        y = pl.pallas_call(
            functools.partial(_kernel_hbm, bk=bk, group_size=g,
                              strategy=strategy),
            grid=(nk, nm, nn),                      # K OUTERMOST: HBM revisits
            in_specs=[
                pl.BlockSpec((bm, bk), lambda ki, mi, ni: (mi, ki)),
                qw_spec_outer,
                pl.BlockSpec((gk, bn), _s_outer),
                pl.BlockSpec((gk, bn // NIB), _s_outer),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda ki, mi, ni: (mi, ni)),
            out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
            interpret=interpret,
        )(x, qweight, scales, qzeros)
        y = y.astype(out_dtype)
    return y[:m, :n]


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult
