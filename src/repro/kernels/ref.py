"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth for kernel tests AND the lowering path
used by the production dry-run (the math — packed-int4 reads, group dequant,
matmul — is identical, so `cost_analysis()` sees the same HBM traffic the TPU
kernel would generate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.serving import kv_quant


def gptq_matmul_ref(x: jnp.ndarray, qweight: jnp.ndarray, scales: jnp.ndarray,
                    qzeros: jnp.ndarray, *, group_size: int,
                    perm: jnp.ndarray | None = None,
                    out_dtype=None) -> jnp.ndarray:
    """y = x @ dequant(qweight)  —  x: (..., K); qweight: (K//8, N) int32.

    scales: (G, N); qzeros: (G, N//8) int32 (col-packed).  ``perm`` is the
    act-order permutation (paper's ``b_q_perm``): qweight rows are in permuted
    order, so activations are gathered first.
    """
    out_dtype = out_dtype or x.dtype
    k = qweight.shape[0] * packing.NIBBLES_PER_WORD
    n = scales.shape[1]
    if perm is not None:
        x = jnp.take(x, perm, axis=-1)
    q = packing.unpack_int4_rows(qweight, k)                    # (K, N) int8
    z = packing.unpack_int4_cols(qzeros, n)                     # (G, N) int8
    g = group_size if group_size > 0 else k
    w = (q.reshape(k // g, g, n).astype(scales.dtype)
         - z[:, None, :].astype(scales.dtype)) * scales[:, None, :]
    w = w.reshape(k, n)
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def dequant_ref(qweight: jnp.ndarray, scales: jnp.ndarray, qzeros: jnp.ndarray,
                *, group_size: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Standalone dequantization (the first pass of the 'naive' strategy)."""
    k = qweight.shape[0] * packing.NIBBLES_PER_WORD
    n = scales.shape[1]
    q = packing.unpack_int4_rows(qweight, k)
    z = packing.unpack_int4_cols(qzeros, n)
    g = group_size if group_size > 0 else k
    w = (q.reshape(k // g, g, n).astype(jnp.float32)
         - z[:, None, :].astype(jnp.float32)) * scales[:, None, :].astype(jnp.float32)
    return w.reshape(k, n).astype(dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Reference attention. q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D). GQA via
    head repetition. Optional causal + sliding-window masking."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned decode support
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                        lengths: jnp.ndarray, *,
                        k_scales: jnp.ndarray | None = None,
                        v_scales: jnp.ndarray | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Oracle for ``kernels/paged_attention.py``: gather every sequence's
    pages into a contiguous (B, max_pages*page_size, Hkv, D) view, then run
    masked grouped attention.  q: (B, H, D); k/v_pages: (P, ps, Hkv, D);
    block_tables: (B, max_pages) int32; lengths: (B,) int32. -> (B, H, D).

    ``k_scales``/``v_scales`` — (P, ps, Hkv) per-token or (P, Hkv) per-page
    symmetric scales for int8 pools (``serving/kv_quant.py``): the oracle
    simply materializes the dequantized pools, which the kernel never does."""
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales, or neither")
    if k_scales is not None:
        k_pages = kv_quant.dequantize(k_pages, k_scales, dtype=jnp.float32)
        v_pages = kv_quant.dequantize(v_pages, v_scales, dtype=jnp.float32)
    b, h, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    rep = h // hkv
    k = k_pages[block_tables].reshape(b, -1, hkv, d)    # (B, maxp*ps, Hkv, D)
    v = v_pages[block_tables].reshape(b, -1, hkv, d)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, rep, d)
    logits = jnp.einsum("bgrd,bkgd->bgrk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = kpos < lengths[:, None]                      # (B, maxp*ps)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # exact-zero masked keys: a lengths[b] == 0 row yields 0 output, matching
    # the kernel's fully-masked-page convention
    p = p * mask[:, None, None]
    out = jnp.einsum("bgrk,bkgd->bgrd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_prefill_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                      v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                      seq_start: jnp.ndarray, lengths: jnp.ndarray, *,
                      k_scales: jnp.ndarray | None = None,
                      v_scales: jnp.ndarray | None = None,
                      scale: float | None = None) -> jnp.ndarray:
    """Oracle for ``kernels/paged_attention.py::paged_prefill``: gather every
    sequence's pages into a contiguous (B, max_pages*page_size, Hkv, D) view
    (densely dequantized when int8 — exactly the materialization the kernel
    exists to avoid), then run causally masked grouped attention over the
    whole suffix block.

    q: (B, S, H, D) — query i of row b sits at absolute position
    ``seq_start[b] + i``; ``lengths``: (B,) total valid keys per row
    (``seq_start + write_lens``), masking right-padded bucket positions and
    unwritten reserve pages.  Fully-masked query rows yield exact zeros,
    matching the kernel's zero-normalizer convention.  -> (B, S, H, D).
    """
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales, or neither")
    if k_scales is not None:
        k_pages = kv_quant.dequantize(k_pages, k_scales, dtype=jnp.float32)
        v_pages = kv_quant.dequantize(v_pages, v_scales, dtype=jnp.float32)
    b, s, h, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    rep = h // hkv
    k = k_pages[block_tables].reshape(b, -1, hkv, d)    # (B, maxp*ps, Hkv, D)
    v = v_pages[block_tables].reshape(b, -1, hkv, d)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, s, hkv, rep, d)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = seq_start[:, None] + jnp.arange(s)[None, :]           # (B, S)
    kpos = jnp.arange(k.shape[1])                                # (K,)
    mask = ((kpos[None, None, :] <= qpos[:, :, None])
            & (kpos[None, None, :] < lengths[:, None, None]))    # (B, S, K)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    p = p * mask[:, None, None]
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def selective_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                       b: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray,
                       h0: jnp.ndarray | None = None):
    """Mamba-1 selective scan oracle.

    x, dt: (B, L, Di); a: (Di, S); b, c: (B, L, S); d: (Di,)
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D*x_t
    Returns (y: (B, L, Di), h_last: (B, Di, S)).
    """
    bsz, length, di = x.shape
    s = a.shape[1]
    xf = x.astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32))

    def step(h, inp):
        # da/dbx computed per timestep — materializing the full (B, L, Di, S)
        # discretization costs 16x the activation bytes (550 TB at train_4k
        # production shape; see EXPERIMENTS.md §Roofline notes)
        x_t, dt_t, b_t, c_t = inp                                # (B,Di),(B,Di),(B,S),(B,S)
        da_t = jnp.exp(dt_t[..., None] * a[None])                # (B, Di, S)
        h = da_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((bsz, di, s), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    hl, ys = jax.lax.scan(step, h0,
                          (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
                           b.transpose(1, 0, 2), c.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xf * d[None, None, :]
    return y.astype(x.dtype), hl
