"""Pallas TPU kernel for the decode fast lane: fused W4A16 GEMV.

Decode is a batch of single-token matvecs — M <= the slot batch (typically 8,
one sublane tile) while K and N are model-sized — so the general
``gptq_matmul`` grid wastes its M tiling and pays one program launch per
(mi, ni, ki) cell.  This kernel is specialized for that shape:

* **N-major grid** ``(N // bn,)`` — one program per output column block; the
  full K reduction happens inside the program (a VMEM ``fori_loop`` over bk
  chunks), so there is no K grid dimension at all.
* **SMB** — the fp32 accumulator lives in VMEM scratch for the whole
  reduction and is written back exactly once.  This grid *is* the SMB
  optimization: strategies with ``accum_vmem=False`` intentionally keep the
  general kernel's K-outermost grid (output block revisited through HBM each
  sweep — the paper's atomicAdd-traffic baseline) and are delegated.
* **VML** — weights stream as packed int32 words (8 nibbles each) and are
  unpacked with vector shifts in-register; ``packed_loads=False`` takes the
  pre-expanded int8 array at 2x the HBM bytes.
* **ILA** — the dequantized (bk, bn) chunk feeds the MXU via ``jnp.dot``
  (decode M pads to a full sublane, so the MXU still helps); ``mxu=False``
  runs the VPU broadcast multiply-add loop.
* **Fused bias** — the bias column block is added during the single
  writeback instead of a separate elementwise pass over (M, N).

Dispatch policy lives in ``kernels/ops.py::gptq_linear`` (M-threshold route:
decode -> here, prefill -> ``gptq_matmul``).  Block sizes come from the
caller or from ``kernels/autotune.py``.  See DESIGN.md §7.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.opt_strategies import KernelStrategy, OPT4GPTQ
from repro.kernels import gptq_matmul as _gm
from repro.kernels.gptq_matmul import (NIB, _compute_tile, _dequant_tile,
                                       _round_up, _unpack_cols_block,
                                       _unpack_rows_block, pad_cols,
                                       resolve_block_sizes)

# M at or below this routes to the GEMV lane (ops.gptq_linear dispatcher):
# one padded sublane tile, the paper's decode regime.
GEMV_M_MAX = 8


def _kernel_gemv(x_ref, qw_ref, s_ref, qz_ref, b_ref, o_ref, acc_ref, *,
                 bk, nk, group_size, strategy: KernelStrategy):
    """One output column block: full-K reduction in VMEM, single writeback.

    The K loop is a *static* Python unroll (nk = K/bk is a trace-time
    constant, small by construction): every ref slice is static, so nothing
    lowers to while-loops or dynamic slices — the chunking only bounds the
    live dequant tile at (bk, bn) instead of (K, bn)."""
    bn = o_ref.shape[1]
    g = group_size
    gk = max(bk // g, 1)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    for j in range(nk):
        if strategy.packed_loads:
            kw = bk // NIB
            w_nib = _unpack_rows_block(qw_ref[j * kw:(j + 1) * kw, :], bk)
        else:
            w_nib = qw_ref[j * bk:(j + 1) * bk, :].astype(jnp.float32)
        goff = (j * bk) // g
        s = s_ref[goff:goff + gk, :].astype(jnp.float32)
        z = _unpack_cols_block(qz_ref[goff:goff + gk, :], bn)
        w = _dequant_tile(w_nib, s, z, bk, g)
        x_chunk = x_ref[:, j * bk:(j + 1) * bk].astype(jnp.float32)
        acc_ref[...] += _compute_tile(x_chunk, w, strategy.mxu)
    o_ref[...] = (acc_ref[...] + b_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "strategy", "bn", "bk", "out_dtype",
                     "interpret"))
def gptq_gemv(x: jnp.ndarray, qweight: jnp.ndarray, scales: jnp.ndarray,
              qzeros: jnp.ndarray, bias: jnp.ndarray | None = None, *,
              group_size: int, strategy: KernelStrategy = OPT4GPTQ,
              bn: int = 256, bk: int = 512, out_dtype=None,
              interpret: bool = True) -> jnp.ndarray:
    """Fused GPTQ GEMV: y = x @ dequant(qweight) + bias for small-M decode.

    x: (M, K) with M <= GEMV_M_MAX (padded to a sublane tile).  qweight is
    (K//8, N) int32 when ``strategy.packed_loads`` else (K, N) int8.  Caller
    applies the act-order permutation to x (see ops.gptq_linear).  Strategies
    without the fused+VMEM-accumulator structure delegate to ``gptq_matmul``
    (their ablation semantics are grid-level, which this lane removes).
    """
    m, k = x.shape
    n = scales.shape[1]
    out_dtype = out_dtype or x.dtype
    if not (strategy.fused and strategy.accum_vmem):
        y = _gm.gptq_matmul(x, qweight, scales, qzeros,
                            group_size=group_size, strategy=strategy,
                            bm=8, bn=bn, bk=bk, out_dtype=out_dtype,
                            interpret=interpret)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y

    g = group_size if group_size > 0 else k
    _, bn, bk = resolve_block_sizes(m, k, n, group_size, 8, bn, bk)
    qweight, scales, qzeros, n_pad = pad_cols(qweight, scales, qzeros, n, bn)
    bm = _round_up(m, 8)
    if bm != m:
        x = jnp.pad(x, ((0, bm - m), (0, 0)))
    if bias is None:
        b = jnp.zeros((1, n_pad), jnp.float32)
    else:
        b = bias.reshape(1, n).astype(jnp.float32)
        if n_pad != n:
            b = jnp.pad(b, ((0, 0), (0, n_pad - n)))

    nn, nk = n_pad // bn, k // bk
    gtot = scales.shape[0]
    if strategy.packed_loads:
        qw_spec = pl.BlockSpec((k // NIB, bn), lambda ni: (0, ni))
    else:
        qw_spec = pl.BlockSpec((k, bn), lambda ni: (0, ni))

    y = pl.pallas_call(
        functools.partial(_kernel_gemv, bk=bk, nk=nk, group_size=g,
                          strategy=strategy),
        grid=(nn,),                                  # N-major, no K dimension
        in_specs=[
            pl.BlockSpec((bm, k), lambda ni: (0, 0)),
            qw_spec,
            pl.BlockSpec((gtot, bn), lambda ni: (0, ni)),
            pl.BlockSpec((gtot, bn // NIB), lambda ni: (0, ni)),
            pl.BlockSpec((1, bn), lambda ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda ni: (0, ni)),
        out_shape=jax.ShapeDtypeStruct((bm, n_pad), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, qweight, scales, qzeros, b)
    return y[:m, :n]
