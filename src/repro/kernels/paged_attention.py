"""Pallas TPU paged-attention kernels: decode (ISSUE 2) and chunked prefill
(ISSUE 5).

Attention over a paged KV cache: each sequence's keys/values live in
non-contiguous fixed-size pages of a shared physical pool, addressed through a
per-sequence block table — the vLLM PagedAttention layout the paper's serving
substrate is built on, mapped to TPU idiom:

* **Grid (B, Hkv, n_pages)** with the block table and sequence lengths as
  *scalar-prefetch* operands: the K/V ``BlockSpec`` index maps read
  ``block_tables[b, p]`` so each program DMAs exactly one physical page into
  VMEM — the gather happens in the memory system, never as a materialized
  (B, L, Hkv, D) copy.
* **Online softmax over pages** — running max ``m``, normalizer ``l`` and an
  fp32 output accumulator live in VMEM scratch across the page loop (same
  scheme as ``flash_attention.py``); one writeback on the last page.
* **GQA without head repetition** — the query block for a kv head is its
  ``rep = H // Hkv`` query heads, shaped (rep, D); logits are (rep, page_size)
  so K/V are read once per kv head, never repeated.
* Pages past a sequence's length (block-table padding points at the null
  page) still execute structurally but are fully masked, mirroring the
  flash kernel's masked-tile convention.
* **Fused int8-KV dequantization** (ISSUE 4) — with ``k_scales``/``v_scales``
  the K/V pools hold int8 payloads and the kernel DMAs the page *plus its
  scales* into VMEM, rescaling inside the online-softmax loop: a floating-
  point copy of the KV cache never exists in HBM.  Scale pools are parallel
  to the page pools — ``(P, page_size, Hkv)`` per-token or ``(P, Hkv)``
  per-page symmetric scales (``serving/kv_quant.py``).

* **Chunked paged prefill** (ISSUE 5) — ``paged_prefill`` runs the *whole
  suffix block* of a (possibly prefix-hit) prompt with online softmax
  directly over the physical pool: grid **(B, Hkv, q-chunks, pages)**, the
  query block a (chunk × rep, D) tile, the causal mask computed from the
  scalar-prefetched per-row start offsets.  This removes the serving
  stack's last materialized KV copy — the old prefill path gathered
  ``kp[block_tables]`` into a contiguous (B, max_pages·page_size, Hkv, D)
  view (and densely dequantized it when int8), doubling peak prefill
  memory.  Both int8 scale granularities dequantize in VMEM here too.

``kernels/ref.py::paged_attention_ref`` / ``paged_prefill_ref`` are the jnp
oracles; ``interpret=True`` (the default) runs these same kernels through
the Pallas interpreter on CPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sm_reset(m_ref, l_ref, acc_ref):
    """Reset the online-softmax VMEM scratch at the first page."""
    m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _sm_update(s, v, m_ref, l_ref, acc_ref):
    """One page of the online softmax, shared by the decode and prefill
    kernels: ``s`` is the fully masked (rows, page_size) fp32 logit block,
    ``v`` the (page_size, D) fp32 value page; the running max ``m``,
    normalizer ``l`` and fp32 output accumulator live in VMEM scratch across
    the page axis."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # fully-masked rows keep m == -inf: use a 0-based exp and zero correction
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    pr = jnp.where(jnp.isfinite(s), jnp.exp(s - safe_m[:, None]), 0.0)
    l_ref[...] = corr * l_ref[...] + pr.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        pr, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _sm_flush(o_ref, m_ref, l_ref, acc_ref):
    """Write back the normalized accumulator (zero for all-masked rows)."""
    denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
    o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype).reshape(o_ref.shape)


def _page_update(q, k, v, b, o_ref, m_ref, l_ref, acc_ref, len_ref, *,
                 page_size, scale):
    """One decode page: q (rep, D); k, v (page_size, D) fp32 in VMEM (already
    dequantized on the int8 path)."""
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _():
        _sm_reset(m_ref, l_ref, acc_ref)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, dimension=1)
    s = jnp.where(kpos < len_ref[b], s, -jnp.inf)
    _sm_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(p == pl.num_programs(2) - 1)
    def _():
        _sm_flush(o_ref, m_ref, l_ref, acc_ref)


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size, scale):
    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)                  # (rep, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page_size, D)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (page_size, D)
    _page_update(q, k, v, b, o_ref, m_ref, l_ref, acc_ref, len_ref,
                 page_size=page_size, scale=scale)


def _dequant_page(k_ref, v_ref, ks_ref, vs_ref, *, per_page):
    """In-VMEM rescale of one int8 page: the page DMA brought the quantized
    payload plus its scales; returns fp32 (page_size, D) k, v — no fp KV is
    ever materialized in HBM."""
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page_size, D) int8
    v = v_ref[0, :, 0].astype(jnp.float32)
    if per_page:                                         # one scale per page
        k = k * ks_ref[0, 0].astype(jnp.float32)
        v = v * vs_ref[0, 0].astype(jnp.float32)
    else:                                                # one per token
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    return k, v


def _kernel_quant(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size, scale, per_page):
    """Int8-KV decode variant: dequantization happens inside the online-
    softmax page loop (see ``_dequant_page``)."""
    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)                  # (rep, D)
    k, v = _dequant_page(k_ref, v_ref, ks_ref, vs_ref, per_page=per_page)
    _page_update(q, k, v, b, o_ref, m_ref, l_ref, acc_ref, len_ref,
                 page_size=page_size, scale=scale)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                    block_tables: jnp.ndarray, lengths: jnp.ndarray, *,
                    k_scales: jnp.ndarray | None = None,
                    v_scales: jnp.ndarray | None = None,
                    scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Single-token decode attention over a paged KV pool.

    q            : (B, H, D) — one query token per sequence.
    k_pages/v_pages: (P, page_size, Hkv, D) physical page pools (int8 when
                   ``k_scales``/``v_scales`` are given).
    block_tables : (B, max_pages) int32 — logical page i of sequence b lives
                   in physical page ``block_tables[b, i]``; padding entries
                   must point at a valid (e.g. null) page.
    lengths      : (B,) int32 — keys at logical positions < lengths[b] attend
                   (the just-written decode token included).
    k_scales/v_scales: optional symmetric dequant scales parallel to the
                   pools — (P, page_size, Hkv) per-token or (P, Hkv)
                   per-page; dequantization is fused into the page loop.
    Returns (B, H, D).
    """
    b, h, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    assert h % hkv == 0, (h, hkv)
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales, or neither")
    rep = h // hkv
    max_pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qg = q.reshape(b, hkv, rep, d)
    in_specs = [
        pl.BlockSpec((1, 1, rep, d), lambda b, h, p, bt, ln: (b, h, 0, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
    ]
    inputs = [qg, k_pages, v_pages]
    if k_scales is None:
        kernel = functools.partial(_kernel, page_size=page_size, scale=scale)
    else:
        per_page = k_scales.ndim == 2          # (P, Hkv) vs (P, ps, Hkv)
        if per_page:
            scale_spec = pl.BlockSpec((1, 1),
                                      lambda b, h, p, bt, ln: (bt[b, p], h))
        else:
            scale_spec = pl.BlockSpec((1, page_size, 1),
                                      lambda b, h, p, bt, ln: (bt[b, p], 0, h))
        in_specs += [scale_spec, scale_spec]
        inputs += [k_scales, v_scales]
        kernel = functools.partial(_kernel_quant, page_size=page_size,
                                   scale=scale, per_page=per_page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda b, h, p, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((rep,), jnp.float32),
                        pltpu.VMEM((rep,), jnp.float32),
                        pltpu.VMEM((rep, d), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *inputs)
    return out.reshape(b, h, d)


# --------------------------------------------------------------------- prefill
def _prefill_update(q, k, v, b, o_ref, m_ref, l_ref, acc_ref, st_ref, len_ref,
                    *, page_size, rep, scale):
    """One prefill page for one query chunk: q (chunk*rep, D) — ``rep`` query
    heads per chunk row, row r is chunk position r // rep; k, v
    (page_size, D) fp32 in VMEM.  The causal mask is computed from the
    scalar-prefetched per-row absolute start offset ``st_ref[b]``; keys past
    ``len_ref[b]`` (right-padded bucket positions, unwritten reserve pages)
    are masked like the decode kernel masks pages past the length.  Pages
    entirely above the chunk's causal horizon or past the row length are
    skipped outright — roughly the upper triangle of the (chunk, page)
    grid, where every logit would mask to -inf."""
    p = pl.program_id(3)

    @pl.when(p == 0)
    def _():
        _sm_reset(m_ref, l_ref, acc_ref)

    # program ids / scalar prefetch reads stay outside the pl.when body
    # (program_id does not lower inside the predicated branch on interpret)
    chunk = q.shape[0] // rep
    q0 = st_ref[b] + pl.program_id(2) * chunk       # tile's first qpos
    kbase = p * page_size
    length = len_ref[b]
    live = (kbase <= q0 + chunk - 1) & (kbase < length)

    @pl.when(live)
    def _():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q0 + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=0) // rep
        kpos = kbase + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1)
        s = jnp.where((kpos <= qpos) & (kpos < length), s, -jnp.inf)
        _sm_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(p == pl.num_programs(3) - 1)
    def _():
        _sm_flush(o_ref, m_ref, l_ref, acc_ref)


def _prefill_kernel(bt_ref, st_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, page_size, rep, scale):
    b = pl.program_id(0)
    q = q_ref[0, 0, 0].astype(jnp.float32)               # (chunk*rep, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page_size, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    _prefill_update(q, k, v, b, o_ref, m_ref, l_ref, acc_ref, st_ref, len_ref,
                    page_size=page_size, rep=rep, scale=scale)


def _prefill_kernel_quant(bt_ref, st_ref, len_ref, q_ref, k_ref, v_ref,
                          ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                          page_size, rep, scale, per_page):
    """Int8-KV prefill variant: page payload + scales arrive in one DMA and
    the rescale happens inside the online-softmax page loop."""
    b = pl.program_id(0)
    q = q_ref[0, 0, 0].astype(jnp.float32)               # (chunk*rep, D)
    k, v = _dequant_page(k_ref, v_ref, ks_ref, vs_ref, per_page=per_page)
    _prefill_update(q, k, v, b, o_ref, m_ref, l_ref, acc_ref, st_ref, len_ref,
                    page_size=page_size, rep=rep, scale=scale)


@functools.partial(jax.jit,
                   static_argnames=("scale", "q_chunk", "interpret"))
def paged_prefill(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                  block_tables: jnp.ndarray, seq_start: jnp.ndarray,
                  lengths: jnp.ndarray, *,
                  k_scales: jnp.ndarray | None = None,
                  v_scales: jnp.ndarray | None = None,
                  scale: float | None = None, q_chunk: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """Chunked prefill attention over a paged KV pool (ISSUE 5 tentpole).

    q            : (B, S, H, D) — the suffix query block; query i of row b
                   sits at absolute position ``seq_start[b] + i`` (its KV
                   must already be written to the pool).
    k_pages/v_pages: (P, page_size, Hkv, D) physical page pools (int8 when
                   ``k_scales``/``v_scales`` are given).
    block_tables : (B, max_pages) int32 — padding entries must point at a
                   valid (e.g. null) page.
    seq_start    : (B,) int32 — prefix-hit length (0 on a cold prefill).
    lengths      : (B,) int32 — total valid keys per row (prefix + real
                   suffix tokens, i.e. ``seq_start + write_lens``); keys at
                   or past this are masked, so right-padded bucket positions
                   never leak into real rows' outputs.
    ``q_chunk`` bounds the query rows per grid step (the VMEM tile is
    (q_chunk·rep, D)); S is padded up to a chunk multiple internally and the
    pad rows' outputs are sliced off.  Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    assert h % hkv == 0, (h, hkv)
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales, or neither")
    rep = h // hkv
    max_pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    chunk = max(1, min(q_chunk, s))
    nq = -(-s // chunk)
    pad = nq * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (B, S, H, D) -> (B, Hkv, nq, chunk*rep, D): one grid step's query tile
    # is a kv head's rep query heads over one chunk of positions
    qg = q.reshape(b, nq, chunk, hkv, rep, d).transpose(0, 3, 1, 2, 4, 5)
    qg = qg.reshape(b, hkv, nq, chunk * rep, d)
    in_specs = [
        pl.BlockSpec((1, 1, 1, chunk * rep, d),
                     lambda b, h, qc, p, bt, st, ln: (b, h, qc, 0, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, qc, p, bt, st, ln: (bt[b, p], 0, h, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, qc, p, bt, st, ln: (bt[b, p], 0, h, 0)),
    ]
    inputs = [qg, k_pages, v_pages]
    if k_scales is None:
        kernel = functools.partial(_prefill_kernel, page_size=page_size,
                                   rep=rep, scale=scale)
    else:
        per_page = k_scales.ndim == 2          # (P, Hkv) vs (P, ps, Hkv)
        if per_page:
            scale_spec = pl.BlockSpec(
                (1, 1), lambda b, h, qc, p, bt, st, ln: (bt[b, p], h))
        else:
            scale_spec = pl.BlockSpec(
                (1, page_size, 1),
                lambda b, h, qc, p, bt, st, ln: (bt[b, p], 0, h))
        in_specs += [scale_spec, scale_spec]
        inputs += [k_scales, v_scales]
        kernel = functools.partial(_prefill_kernel_quant, page_size=page_size,
                                   rep=rep, scale=scale, per_page=per_page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, nq, max_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, chunk * rep, d),
                               lambda b, h, qc, p, bt, st, ln: (b, h, qc, 0, 0)),
        scratch_shapes=[pltpu.VMEM((chunk * rep,), jnp.float32),
                        pltpu.VMEM((chunk * rep,), jnp.float32),
                        pltpu.VMEM((chunk * rep, d), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, nq, chunk * rep, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_start.astype(jnp.int32),
      lengths.astype(jnp.int32), *inputs)
    out = out.reshape(b, hkv, nq, chunk, rep, d).transpose(0, 2, 3, 1, 4, 5)
    return out.reshape(b, nq * chunk, h, d)[:, :s]
