"""Block-size autotuner for the GPTQ Pallas kernels.

Three stages (DESIGN.md §8):

1. **Enumerate** (8,128)-aligned (bm, bn, bk) candidates legal for the shape
   (bk divides K and aligns with the quantization group; bn divides N when it
   can, else falls back to the padded-N block).
2. **Prune** with the analytic v5e cost model (``core/perf_model``): only
   candidates within ``PRUNE_FACTOR`` of the best modeled time are timed —
   the model ranks bk (HBM sweep count); timing resolves bm/bn ties.
3. **Time** the survivors on synthetic data (packed int32 weights, the real
   kernel entry points) and persist the winner to a JSON cache keyed by
   ``(M, K, N, group_size, strategy, lane)`` where lane is "gemv"
   (M <= GEMV_M_MAX -> ``gptq_gemv``) or "matmul" (-> ``gptq_matmul``).

The cache file defaults to ``~/.cache/repro/autotune.json`` and is overridden
by ``$REPRO_AUTOTUNE_CACHE``.  Lookups go memory -> file -> tune; a repeated
key never re-times (the test suite asserts this via ``timed_keys``).
Surface: ``KernelConfig(block_sizes="auto")`` in ``models/layers.py`` routes
``ops.gptq_linear`` through ``get_block_sizes``.  Timing uses concrete
synthetic arrays, so it executes (not traces) even when the lookup happens
while an outer ``jit`` is tracing the model.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.opt_strategies import KernelStrategy, OPT4GPTQ
from repro.core.perf_model import gptq_matmul_cost
from repro.kernels import gptq_gemv as _gemv
from repro.kernels import gptq_matmul as _gm
from repro.kernels.gptq_gemv import GEMV_M_MAX

ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                             "autotune.json")
PRUNE_FACTOR = 1.5        # modeled-time ratio beyond which candidates drop
MAX_TIMED = 6             # hard cap on survivors that get wall-clock timed
TIMING_REPS = 2

BM_CANDIDATES = (8, 16, 32, 64, 128)
BN_CANDIDATES = (64, 128, 256, 512, 1024)
BK_CANDIDATES = (64, 128, 256, 512, 1024)

_MEM: dict[str, tuple[int, int, int]] = {}
timed_keys: list[str] = []      # every key that ran wall-clock timing (tests)


def cache_path() -> str:
    return os.environ.get(ENV_CACHE, DEFAULT_CACHE)


def clear_memory_cache() -> None:
    _MEM.clear()


def _lane(m: int) -> str:
    return "gemv" if m <= GEMV_M_MAX else "matmul"


def cache_key(m: int, k: int, n: int, group_size: int,
              strategy: KernelStrategy, *, interpret: bool = True) -> str:
    """Includes the execution mode: interpreter-mode timings (CPU dev box)
    must never be reused for compiled-TPU runs — the two wall-clock signals
    are uncorrelated, so each mode tunes and caches independently."""
    mode = "interp" if interpret else "compiled"
    return f"{m}x{k}x{n}:g{group_size}:{strategy.name}:{_lane(m)}:{mode}"


# ----------------------------------------------------------------- candidates
def candidate_blocks(m: int, k: int, n: int,
                     group_size: int) -> list[tuple[int, int, int]]:
    """Legal (8,128)-aligned blocks for the shape.  The GEMV lane pins bm to
    the padded sublane tile; bk must divide K and align with the group."""
    g = group_size if group_size > 0 else k
    m_pad = _gm._round_up(m, 8)
    if m <= GEMV_M_MAX:
        bms = [m_pad]
    else:
        bms = sorted({min(b, m_pad) for b in BM_CANDIDATES})
    bns = [b for b in BN_CANDIDATES if b <= n and n % b == 0]
    if not bns:
        bns = [min(_gm._round_up(n, 8), 256)]     # padded-N fallback block
    bks = [b for b in BK_CANDIDATES
           if b <= k and k % b == 0 and (b % g == 0 or g % b == 0)]
    if not bks:
        bks = [_gm.resolve_block_sizes(m, k, n, group_size, 8, 256, 512)[2]]
    return [(bm, bn, bk) for bm in bms for bn in bns for bk in bks]


def prune_candidates(cands: list[tuple[int, int, int]], m: int, k: int,
                     n: int, group_size: int, strategy: KernelStrategy,
                     *, max_timed: int = MAX_TIMED
                     ) -> list[tuple[int, int, int]]:
    """Rank by the analytic cost model and keep the near-optimal front.

    The model only sees bk (HBM sweep count), so many (bm, bn) variants tie;
    ties break toward larger tiles — fewer program launches — so the timed
    set spans the configs that actually differ at runtime."""
    scored = sorted(
        ((gptq_matmul_cost(m, k, n, group_size=group_size, strategy=strategy,
                           bk=bk).time_s, (bm, bn, bk))
         for bm, bn, bk in cands),
        key=lambda e: (e[0], -e[1][1] * e[1][2], -e[1][0]))
    best = scored[0][0]
    return [c for t, c in scored if t <= best * PRUNE_FACTOR][:max_timed]


# --------------------------------------------------------------------- timing
def _synthetic(m: int, k: int, n: int, group_size: int,
               strategy: KernelStrategy):
    rng = np.random.default_rng(0)
    g = group_size if group_size > 0 else k
    qweight = jnp.asarray(
        rng.integers(0, 1 << 32, size=(k // packing.NIBBLES_PER_WORD, n),
                     dtype=np.uint64).astype(np.uint32).view(np.int32))
    if not strategy.packed_loads:
        qweight = packing.unpack_int4_rows(qweight, k)
    scales = jnp.asarray(rng.uniform(0.005, 0.02, (k // g, n)).astype(np.float32))
    qzeros = jnp.asarray(
        rng.integers(0, 1 << 32, size=(k // g, n // packing.NIBBLES_PER_WORD),
                     dtype=np.uint64).astype(np.uint32).view(np.int32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    return x, qweight, scales, qzeros


def _time_call(fn, reps: int = TIMING_REPS) -> float:
    jax.block_until_ready(fn())                      # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_block_sizes(m: int, k: int, n: int, group_size: int,
                         strategy: KernelStrategy = OPT4GPTQ, *,
                         interpret: bool = True,
                         max_timed: int = MAX_TIMED
                         ) -> tuple[int, int, int]:
    """Enumerate -> prune -> time; returns the fastest (bm, bn, bk)."""
    survivors = prune_candidates(
        candidate_blocks(m, k, n, group_size), m, k, n, group_size, strategy,
        max_timed=max_timed)
    timed_keys.append(cache_key(m, k, n, group_size, strategy,
                                interpret=interpret))
    if len(survivors) == 1:
        return survivors[0]
    x, qw, scales, qzeros = _synthetic(m, k, n, group_size, strategy)
    lane = _lane(m)
    best_t, best_c = float("inf"), survivors[0]
    for bm, bn, bk in survivors:
        if lane == "gemv":
            fn = lambda: _gemv.gptq_gemv(
                x, qw, scales, qzeros, None, group_size=group_size,
                strategy=strategy, bn=bn, bk=bk, interpret=interpret)
        else:
            fn = lambda: _gm.gptq_matmul(
                x, qw, scales, qzeros, group_size=group_size,
                strategy=strategy, bm=bm, bn=bn, bk=bk, interpret=interpret)
        t = _time_call(fn)
        if t < best_t:
            best_t, best_c = t, (bm, bn, bk)
    return best_c


# ---------------------------------------------------------------- persistence
def _load_file(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_file(path: str, data: dict) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def _q_chunk_lookup(key: str, path: str | None, tune) -> int:
    """Shared memory -> JSON file -> tune cache walk for scalar entries."""
    path = path or cache_path()
    mem_key = f"{path}|{key}"
    hit = _MEM.get(mem_key)
    if hit is not None:
        return int(hit)
    data = _load_file(path)
    if key in data:
        val = int(data[key])
    else:
        val = int(tune())
        data = _load_file(path)                  # re-read: concurrent writers
        data[key] = val
        try:
            _save_file(path, data)
        except OSError:
            pass                                 # read-only FS: memory only
    _MEM[mem_key] = val
    return val


def get_block_sizes(m: int, k: int, n: int, group_size: int,
                    strategy: KernelStrategy = OPT4GPTQ, *,
                    interpret: bool = True,
                    path: str | None = None) -> tuple[int, int, int]:
    """Cached autotune lookup: memory -> JSON file -> tune (and persist).

    The memory cache is scoped per cache file, so an explicit ``path`` (e.g.
    a pinned per-deployment config) is never shadowed by an earlier lookup of
    the same shape against a different file."""
    key = cache_key(m, k, n, group_size, strategy, interpret=interpret)
    path = path or cache_path()
    mem_key = f"{path}|{key}"
    hit = _MEM.get(mem_key)
    if hit is not None:
        return hit
    data = _load_file(path)
    if key in data:
        cfg = tuple(int(v) for v in data[key])
    else:
        cfg = autotune_block_sizes(m, k, n, group_size, strategy,
                                   interpret=interpret)
        data = _load_file(path)                  # re-read: concurrent writers
        data[key] = list(cfg)
        try:
            _save_file(path, data)
        except OSError:
            pass                                 # read-only FS: memory only
    _MEM[mem_key] = cfg
    return cfg


# ------------------------------------------------------- paged-prefill q_chunk
# ISSUE 10 satellite: the chunked-prefill query tile height used to be a
# fixed 128; ``KernelConfig(q_chunk="auto")`` co-tunes it with the engine's
# step token budget.  Candidates stay lane-aligned (multiples of 128) and
# never exceed the suffix length's bucket — a taller tile than the block is
# pure pad work.
Q_CHUNK_CANDIDATES = (128, 256, 512)


def q_chunk_cache_key(s: int, h: int, hkv: int, d: int, page_size: int, *,
                      interpret: bool = True) -> str:
    mode = "interp" if interpret else "compiled"
    return f"qchunk:s{s}:h{h}:kv{hkv}:d{d}:ps{page_size}:{mode}"


def q_chunk_candidates(s: int) -> list[int]:
    cands = [c for c in Q_CHUNK_CANDIDATES if c <= max(s, Q_CHUNK_CANDIDATES[0])]
    return cands or [Q_CHUNK_CANDIDATES[0]]


def autotune_q_chunk(s: int, h: int, hkv: int, d: int, page_size: int, *,
                     interpret: bool = True) -> int:
    """Wall-clock the chunked paged-prefill kernel per candidate tile height
    on synthetic pools and return the fastest ``q_chunk``."""
    from repro.kernels import paged_attention as PA
    cands = q_chunk_candidates(s)
    timed_keys.append(q_chunk_cache_key(s, h, hkv, d, page_size,
                                        interpret=interpret))
    if len(cands) == 1:
        return cands[0]
    rng = np.random.default_rng(0)
    n_pages = -(-s // page_size)
    q = jnp.asarray(rng.normal(size=(1, s, h, d)).astype(np.float32))
    kp = jnp.asarray(
        rng.normal(size=(n_pages + 1, page_size, hkv, d)).astype(np.float32))
    vp = jnp.asarray(
        rng.normal(size=(n_pages + 1, page_size, hkv, d)).astype(np.float32))
    bt = jnp.arange(1, n_pages + 1, dtype=jnp.int32)[None]
    start = jnp.zeros((1,), jnp.int32)
    lengths = jnp.full((1,), s, jnp.int32)
    best_t, best_c = float("inf"), cands[0]
    for qc in cands:
        fn = lambda: PA.paged_prefill(q, kp, vp, bt, start, lengths,
                                      q_chunk=qc, interpret=interpret)
        t = _time_call(fn)
        if t < best_t:
            best_t, best_c = t, qc
    return best_c


def get_q_chunk(s: int, h: int, hkv: int, d: int, page_size: int, *,
                interpret: bool = True, path: str | None = None) -> int:
    """Cached ``q_chunk`` lookup: memory -> JSON file -> tune (and persist)."""
    key = q_chunk_cache_key(s, h, hkv, d, page_size, interpret=interpret)
    return _q_chunk_lookup(
        key, path,
        lambda: autotune_q_chunk(s, h, hkv, d, page_size, interpret=interpret))
