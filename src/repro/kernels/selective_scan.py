"""Pallas TPU selective-scan (Mamba-1) kernel — the deployment answer to
EXPERIMENTS.md §Perf cell D: the SSM state (bd, S) lives in a VMEM scratch
across all timesteps, so HBM traffic is just the x/dt/B/C streams + one final
state writeback, instead of the jnp scan's per-step (B, Di, S) state
round-trip (4096x/layer at train_4k).

Grid (B, Di/bd, L/bl), L innermost (arbitrary semantics). Discretization is
computed per timestep in-register (never materializing (B, L, Di, S) — the
cell-D lesson applied in-kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
            y_ref, hout_ref, h_ref, *, bl):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _():
        h_ref[...] = h0_ref[...][0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                     # (bd, S)
    dvec = d_ref[...].astype(jnp.float32)                  # (bd,)

    # NB: slice-based ref indexing throughout — integer indices in ref
    # load/store tuples break the interpret-mode discharge rules on some
    # jax versions (`'int' object has no attribute 'shape'`).
    def step(j, h):
        row = (slice(0, 1), pl.ds(j, 1), slice(None))
        xt = pl.load(x_ref, row)[0, 0].astype(jnp.float32)           # (bd,)
        dt = jax.nn.softplus(pl.load(dt_ref, row)[0, 0].astype(jnp.float32))
        bt = pl.load(b_ref, row)[0, 0].astype(jnp.float32)           # (S,)
        ct = pl.load(c_ref, row)[0, 0].astype(jnp.float32)
        da = jnp.exp(dt[:, None] * a)                      # (bd, S)
        h = da * h + (dt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + dvec * xt
        pl.store(y_ref, row, y[None, None, :].astype(y_ref.dtype))
        return h

    h_ref[...] = jax.lax.fori_loop(0, bl, step, h_ref[...])

    @pl.when(li == pl.num_programs(2) - 1)
    def _():
        hout_ref[...] = h_ref[...][None].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bl", "interpret"))
def selective_scan(x, dt, a, b, c, d, h0=None, *, bd: int = 512,
                   bl: int = 128, interpret: bool = True):
    """x, dt: (B, L, Di); a: (Di, S); b, c: (B, L, S); d: (Di,);
    h0: (B, Di, S) or None. Returns (y (B, L, Di), h_last (B, Di, S))."""
    bsz, length, di = x.shape
    s = a.shape[1]
    bd = min(bd, di)
    bl = min(bl, length)
    assert di % bd == 0 and length % bl == 0, (di, length, bd, bl)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, s), jnp.float32)

    grid = (bsz, di // bd, length // bl)
    y, h_last = pl.pallas_call(
        functools.partial(_kernel, bl=bl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda bi, di_, li: (bi, li, di_)),  # x
            pl.BlockSpec((1, bl, bd), lambda bi, di_, li: (bi, li, di_)),  # dt
            pl.BlockSpec((bd, s), lambda bi, di_, li: (di_, 0)),           # a
            pl.BlockSpec((1, bl, s), lambda bi, di_, li: (bi, li, 0)),     # b
            pl.BlockSpec((1, bl, s), lambda bi, di_, li: (bi, li, 0)),     # c
            pl.BlockSpec((bd,), lambda bi, di_, li: (di_,)),               # d
            pl.BlockSpec((1, bd, s), lambda bi, di_, li: (bi, di_, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, bl, bd), lambda bi, di_, li: (bi, li, di_)),
            pl.BlockSpec((1, bd, s), lambda bi, di_, li: (bi, di_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, length, di), x.dtype),
            jax.ShapeDtypeStruct((bsz, di, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, s), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, d, h0)
    return y, h_last
