"""Pallas TPU flash attention (prefill): online-softmax tiling so the (Sq, Sk)
logits never leave VMEM — the attention-side complement to the paper's
memory-centric kernel work (DESIGN.md §6 tiling conventions).

Grid (B*H, Sq/bq, Sk/bk), K innermost (arbitrary); VMEM carries the running
max m, normalizer l, and output accumulator per (bq, d) block. Causal blocks
above the diagonal are masked; fully-masked tiles still execute (structural
grid) — block-level early-exit is a TPU-side optimization left to the
compiler's dimension semantics.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, bq, bk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # guard fully-masked rows (m == -inf): exp(-inf - -inf) -> use 0 correction
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    p = jnp.exp(jnp.where(jnp.isfinite(s), s - safe_m[:, None], -jnp.inf))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l_new = corr * l_ref[...] + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q, k, v: (B, S, H, D) with H == Hkv (repeat GQA outside). -> (B,S,H,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    scale = 1.0 / math.sqrt(d)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk),
        grid=(b * h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
