"""Public jit'd wrappers dispatching Pallas kernels vs pure-jnp references.

``use_pallas=False`` (default on this CPU container / for the dry-run) routes
to the ref oracle — identical math and HBM traffic; ``use_pallas=True``
invokes the Pallas kernels (interpret mode on CPU, compiled on real TPU).

The Pallas path has two lanes (DESIGN.md §7):

* **decode** (M <= ``gptq_gemv.GEMV_M_MAX``): the fused W4A16 GEMV kernel —
  N-major grid, full-K VMEM reduction, fused bias add.
* **prefill/train** (larger M): the general tiled ``gptq_matmul``.

``block_sizes`` may be a concrete (bm, bn, bk) tuple, ``None`` (kernel
defaults), or the string ``"auto"`` — the per-shape autotuner cache
(``kernels/autotune.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing
from repro.core.gptq import QuantizedLinear
from repro.core.opt_strategies import KernelStrategy, OPT4GPTQ
from repro.kernels import gptq_gemv as _gemv
from repro.kernels import gptq_matmul as _gm
from repro.kernels import ref as _ref
from repro.kernels.gptq_gemv import GEMV_M_MAX


def gptq_linear(ql: QuantizedLinear, x: jnp.ndarray, *,
                strategy: KernelStrategy = OPT4GPTQ,
                use_pallas: bool = False, interpret: bool = True,
                block_sizes: tuple[int, int, int] | str | None = None
                ) -> jnp.ndarray:
    """y = x @ dequant(W) + bias  for x of shape (..., K)."""
    k, n = ql.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if ql.perm is not None:
        x2 = jnp.take(x2, ql.perm, axis=-1)         # exllama-style b_q_perm
    m = x2.shape[0]

    if use_pallas:
        qw = (ql.qweight if strategy.packed_loads
              else packing.unpack_int4_rows(ql.qweight, k))   # VML-off: int8 2x
        if block_sizes == "auto":
            from repro.kernels import autotune                # lazy: optional
            block_sizes = autotune.get_block_sizes(
                m, k, n, ql.group_size, strategy, interpret=interpret)
        if m <= GEMV_M_MAX:
            # decode fast lane: fused GEMV with bias folded into writeback
            kwargs = {}
            if block_sizes is not None:
                kwargs = dict(zip(("bn", "bk"), block_sizes[1:]))
            y = _gemv.gptq_gemv(x2, qw, ql.scales, ql.qzeros, ql.bias,
                                group_size=ql.group_size, strategy=strategy,
                                out_dtype=x.dtype, interpret=interpret,
                                **kwargs)
            return y.reshape(*lead, n)
        kwargs = {}
        if block_sizes is not None:
            kwargs = dict(zip(("bm", "bn", "bk"), block_sizes))
        y = _gm.gptq_matmul(x2, qw, ql.scales, ql.qzeros,
                            group_size=ql.group_size, strategy=strategy,
                            out_dtype=x.dtype, interpret=interpret, **kwargs)
    else:
        y = _ref.gptq_matmul_ref(x2, ql.qweight, ql.scales, ql.qzeros,
                                 group_size=ql.group_size, perm=None,
                                 out_dtype=x.dtype)
    if ql.bias is not None:
        y = y + ql.bias.astype(y.dtype)
    return y.reshape(*lead, n)
