"""Mamba-1 block (falcon-mamba / hymba SSM head): causal depthwise conv +
selective scan. Decode carries (conv_state, ssm_state) — O(1) per token."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ref import selective_scan_ref
from repro.models import layers as L


def mamba_init(rng, cfg: ModelConfig, dtype=jnp.float32):
    d, di, s, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(rng, 5)
    a = jnp.broadcast_to(jnp.arange(1, s + 1, dtype=jnp.float32)[None, :], (di, s))
    return {
        "in_proj": L.linear_init(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.linear_init(ks[2], di, dr + 2 * s, dtype=dtype),
        "dt_proj": L.linear_init(ks[3], dr, di, bias=True, dtype=dtype),
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": L.linear_init(ks[4], di, d, dtype=dtype),
    }


def _causal_conv(x, w, b, conv_state=None):
    """x: (B, S, Di); w: (K, Di) depthwise. conv_state: (B, K-1, Di) history."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                     # (B, S+K-1, Di)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return out + b[None, None, :], new_state


def mamba_apply(p, x, *, cfg: ModelConfig, kernels=L.DEFAULT_KERNELS,
                cache=None):
    """Returns (y, new_cache). cache = {"conv": (B,K-1,Di), "ssm": (B,Di,S)}."""
    b, s, d = x.shape
    di, ds, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = L.linear(p["in_proj"], x, name="in_proj", kernels=kernels)
    xi, z = xz[..., :di], xz[..., di:]

    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype), conv_state)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    xdbc = L.linear(p["x_proj"], xi, name="x_proj", kernels=kernels)
    dt = L.linear(p["dt_proj"], xdbc[..., :dr], name="dt_proj", kernels=kernels)
    bmat = xdbc[..., dr:dr + ds].astype(jnp.float32)            # (B,S,ds)
    cmat = xdbc[..., dr + ds:].astype(jnp.float32)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))                # (Di, ds)
    h0 = cache["ssm"] if cache is not None else None
    y, h_last = selective_scan_ref(xi, dt, a, bmat, cmat,
                                   p["D"].astype(jnp.float32), h0=h0)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = L.linear(p["out_proj"], y, name="out_proj", kernels=kernels)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h_last}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
