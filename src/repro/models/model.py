"""Top-level language model: embeddings -> layer groups -> norm -> logits.

One class covers all 10 assigned families; behaviour is driven entirely by
``ModelConfig`` (see ``blocks.layer_groups``).  The ``batch`` dict protocol:

  train   : {"tokens": (B,S) i32, "labels": (B,S) i32, "loss_mask": (B,S) f32?}
  prefill : {"tokens": (B,S)} (+ cache, seq_lens)
  decode  : {"tokens": (B,1)} (+ cache, seq_lens)
  frontends (audio/vlm stubs): "input_embeds" (B,S,d), "embed_mask" (B,S) bool
  qwen2-vl M-RoPE: "positions" (3,B,S) i32
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ------------------------------------------------------------------ params
    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        groups = B.layer_groups(cfg)
        ks = jax.random.split(rng, len(groups) + 3)
        params: dict = {"embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype)}
        for i, (count, kind) in enumerate(groups):
            params[f"group{i}"] = B.group_init(ks[i + 1], cfg, count, kind, dtype)
        params["final_norm"] = L.norm_init(cfg.d_model, cfg.norm_type, dtype)
        if not cfg.tie_embeddings:
            params["head"] = L.linear_init(ks[-1], cfg.d_model, cfg.vocab_size,
                                           dtype=dtype)
        if cfg.meta_tokens:
            params["meta"] = (jax.random.normal(ks[-2], (cfg.meta_tokens, cfg.d_model),
                                                dtype) * 0.02)
        return params

    def abstract_params(self, rng=None) -> Any:
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------------------------------------------ embed
    def _embed(self, params, batch, dtype):
        tokens = batch["tokens"]
        x = L.embed_lookup(params["embed"], tokens, dtype)
        if "input_embeds" in batch:
            emb = batch["input_embeds"].astype(dtype)
            if "embed_mask" in batch:     # vlm: splice vision embeds into text
                x = jnp.where(batch["embed_mask"][..., None], emb, x)
            else:                         # audio: frontend output replaces embed
                x = emb
        return x

    def _positions(self, batch, b, s, offset):
        if "positions" in batch:
            return batch["positions"]
        pos = jnp.arange(s, dtype=jnp.int32)[None, :] + jnp.zeros((b, 1), jnp.int32)
        if isinstance(offset, jnp.ndarray):
            pos = pos + offset[:, None]
        else:
            pos = pos + offset
        return pos

    # ----------------------------------------------------------------- forward
    def hidden(self, params, batch, *, kernels=L.DEFAULT_KERNELS,
               cache=None, seq_lens=None, mode: str = "train",
               block_tables=None, write_lens=None):
        """Backbone forward -> (final-norm hidden states, new_cache, aux)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = self._embed(params, batch, dtype)
        b, s = x.shape[:2]
        nmeta = cfg.meta_tokens

        if nmeta and cache is None:       # prepend learned meta tokens (hymba)
            meta = jnp.broadcast_to(params["meta"][None], (b, nmeta, cfg.d_model))
            x = jnp.concatenate([meta.astype(dtype), x], axis=1)
            s = s + nmeta

        offset = seq_lens if (cache is not None and seq_lens is not None) else 0
        positions = self._positions(batch, b, s, offset)

        aux_total = jnp.zeros((), jnp.float32)
        new_cache: dict | None = {} if cache is not None else None
        remat = cfg.remat if mode == "train" else "none"
        x = L.constrain_act(x)
        for i, (count, kind) in enumerate(B.layer_groups(cfg)):
            c = cache.get(f"group{i}") if cache is not None else None
            x, nc, aux = B.group_apply(
                params[f"group{i}"], x, cfg=cfg, kind=kind, count=count,
                kernels=kernels, positions=positions, cache=c,
                seq_lens=seq_lens, num_sink=nmeta, remat=remat,
                block_tables=block_tables, write_lens=write_lens)
            if new_cache is not None:
                new_cache[f"group{i}"] = nc
            aux_total = aux_total + aux

        x = L.apply_norm(params["final_norm"], x, norm_type=cfg.norm_type,
                         eps=cfg.norm_eps)
        if nmeta and cache is None:
            x = x[:, nmeta:]
        return x, new_cache, aux_total

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return L.embed_logits(params["embed"], x)
        return L.linear(params["head"], x.astype(jnp.float32),
                        name="head").astype(jnp.float32)

    def apply(self, params, batch, *, kernels=L.DEFAULT_KERNELS,
              cache=None, seq_lens=None, mode: str = "train",
              block_tables=None, write_lens=None):
        """Returns (logits, new_cache, aux). Full-sequence (train/prefill) when
        cache is None or decode-with-cache otherwise."""
        x, new_cache, aux_total = self.hidden(
            params, batch, kernels=kernels, cache=cache, seq_lens=seq_lens,
            mode=mode, block_tables=block_tables, write_lens=write_lens)
        return self._logits(params, x), new_cache, aux_total

    # ------------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16,
                   kv_quant=None):
        """Slot-layout cache tree.  ``kv_quant`` (a quantized
        ``serving/kv_quant.py::KVQuantConfig``) switches the attention
        payloads to int8 with parallel per-token scale arrays — full-attention
        GQA stacks only (DESIGN.md §12)."""
        cfg = self.cfg
        cache = {}
        total = max_len + cfg.meta_tokens
        for i, (count, kind) in enumerate(B.layer_groups(cfg)):
            cache[f"group{i}"] = B.group_cache_init(cfg, kind, count, batch_size,
                                                    total, dtype, kv_quant)
        return cache

    def init_paged_cache(self, num_pages: int, page_size: int,
                         dtype=jnp.bfloat16, kv_quant=None):
        """Paged-layout cache tree (DESIGN.md §10): per-group physical page
        pools addressed by a shared block table.  Requires a homogeneous
        full-attention stack with no meta tokens.  ``kv_quant`` adds int8
        pools with parallel per-token scale pools (DESIGN.md §12).  The
        dtype default mirrors ``init_cache``; the serving engine always
        passes ``kv_cache.DEFAULT_CACHE_DTYPE`` explicitly."""
        cfg = self.cfg
        if cfg.meta_tokens:
            raise ValueError("paged cache layout does not support meta tokens")
        cache = {}
        for i, (count, kind) in enumerate(B.layer_groups(cfg)):
            cache[f"group{i}"] = B.group_paged_cache_init(
                cfg, kind, count, num_pages, page_size, dtype, kv_quant)
        return cache

    # -------------------------------------------------------------------- loss
    LOSS_CHUNK = 1024   # sequence rows per logits block (memory-bounded CE)

    def loss_fn(self, params, batch, *, kernels=L.DEFAULT_KERNELS):
        x, _, aux = self.hidden(params, batch, kernels=kernels, mode="train")
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        b, s, d = x.shape

        def ce(xc, lc, mc):
            logits = self._logits(params, xc)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
            return jnp.sum(nll * mc)

        c = self.LOSS_CHUNK
        if s > c and s % c == 0:
            # chunked cross-entropy: the (B, S, V) fp32 logits tensor never
            # materializes; backward recomputes each chunk (jax.checkpoint)
            nc = s // c
            xs = (jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0),
                  jnp.moveaxis(labels.reshape(b, nc, c), 1, 0),
                  jnp.moveaxis(mask.reshape(b, nc, c), 1, 0))
            nll_chunks = jax.lax.map(
                jax.checkpoint(lambda args: ce(*args)), xs)
            nll_sum = jnp.sum(nll_chunks)
        else:
            nll_sum = ce(x, labels, mask)
        loss = nll_sum / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + aux, {"loss": loss, "aux": aux}

    # ----------------------------------------------------------- serving steps
    def forward_chunks(self, params, tokens, chunk_lens, cache, seq_lens, *,
                       kernels=L.DEFAULT_KERNELS, block_tables=None,
                       extra=None):
        """Unified serving forward (ISSUE 10, DESIGN.md §18): every row is
        one (chunk_start=seq_lens, chunk_len) span of its sequence — decode
        is a 1-token chunk, chunked prefill a budget-sized chunk, spec-verify
        a (k+1)-token chunk — all through the same cached multi-token path.

        tokens     : (B, C) int32, right-padded past ``chunk_lens``.
        chunk_lens : (B,) int32 real tokens per row; padded (and dead-row)
                     positions' cache writes are null-routed (paged) or
                     dropped (slot), and their keys are masked out of every
                     row's attention window.
        Row positions start at the absolute offset ``seq_lens``.  Returns
        (logits (B, C, V) fp32, new_cache); the caller advances seq_lens.
        """
        batch = {"tokens": tokens}
        if extra:
            batch.update(extra)
        logits, cache, _ = self.apply(
            params, batch, kernels=kernels, cache=cache, seq_lens=seq_lens,
            mode="decode", block_tables=block_tables, write_lens=chunk_lens)
        return logits, cache

    def prefill(self, params, batch, cache, seq_lens, *,
                kernels=L.DEFAULT_KERNELS, true_lengths=None,
                block_tables=None):
        """Whole-prompt convenience wrapper over ``forward_chunks``; returns
        logits of the last *real* position (``true_lengths`` handles
        right-padded bucketed prompts), new cache, new seq_lens."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        if cfg.meta_tokens:
            # engine guarantees seq_lens==0 at prefill; meta tokens fill the
            # cache prefix first
            meta_batch = {"tokens": jnp.zeros((b, cfg.meta_tokens), jnp.int32),
                          "input_embeds": jnp.broadcast_to(
                              params["meta"][None],
                              (b, cfg.meta_tokens, cfg.d_model))}
            _, cache, _ = self.apply(params, meta_batch, kernels=kernels,
                                     cache=cache, seq_lens=seq_lens,
                                     mode="prefill")
            seq_lens = seq_lens + cfg.meta_tokens
        # bucketed prompts: padded positions' cache writes are masked on
        # every layout — routed to the null page (paged) or dropped (slot);
        # real writes cover true_lengths tokens of the block
        if true_lengths is None:
            chunk_lens = jnp.full((b,), s, jnp.int32)
        else:
            chunk_lens = true_lengths.astype(jnp.int32)
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        logits, cache = self.forward_chunks(
            params, tokens, chunk_lens, cache, seq_lens, kernels=kernels,
            block_tables=block_tables, extra=extra)
        if true_lengths is None:
            last = logits[:, -1]
        else:
            idx = (true_lengths - 1).astype(jnp.int32)
            last = jnp.take_along_axis(
                logits, idx[:, None, None].clip(0), axis=1)[:, 0]
        return last, cache, seq_lens + s

    def decode_step(self, params, tokens, cache, seq_lens, *,
                    kernels=L.DEFAULT_KERNELS, extra=None, block_tables=None):
        """tokens: (B, 1). Returns (logits (B, V), cache, seq_lens+1).
        One-token-chunk wrapper over ``forward_chunks``."""
        b, s = tokens.shape
        logits, cache = self.forward_chunks(
            params, tokens, jnp.full((b,), s, jnp.int32), cache, seq_lens,
            kernels=kernels, block_tables=block_tables, extra=extra)
        return logits[:, -1], cache, seq_lens + 1


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
