"""Transformer block assembly.

A model is a sequence of *layer groups*: (count, BlockKind) with parameters
stacked over the count dimension and applied with ``lax.scan`` (O(1) HLO size
— mandatory for 64-80 layer dry-run compiles). Groups exist because layers can
be heterogeneous: hymba interleaves global-attention layers among SWA layers,
deepseek-v2 has a leading dense-FFN layer before the MoE stack.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import layers as L
from repro.models import mamba as M


@dataclasses.dataclass(frozen=True)
class BlockKind:
    attn: str = "full"        # full | swa | mla | none
    ffn: str = "dense"        # dense | moe | none
    ssm: bool = False


def layer_groups(cfg: ModelConfig) -> list[tuple[int, BlockKind]]:
    """Derive the group structure from the config."""
    if cfg.family == "ssm":
        return [(cfg.num_layers, BlockKind(attn="none", ffn="none", ssm=True))]
    if cfg.family == "hybrid":
        groups: list[tuple[int, BlockKind]] = []
        glob = set(cfg.global_attn_layers)
        i = 0
        while i < cfg.num_layers:
            if i in glob:
                groups.append((1, BlockKind(attn="full", ffn="dense", ssm=True)))
                i += 1
            else:
                j = i
                while j < cfg.num_layers and j not in glob:
                    j += 1
                groups.append((j - i, BlockKind(attn="swa", ffn="dense", ssm=True)))
                i = j
        return groups
    attn = "mla" if cfg.attn_type == "mla" else "full"
    if cfg.num_experts:
        groups = []
        if cfg.first_dense_layers:
            groups.append((cfg.first_dense_layers, BlockKind(attn=attn, ffn="dense")))
        groups.append((cfg.num_layers - cfg.first_dense_layers,
                       BlockKind(attn=attn, ffn="moe")))
        return groups
    if cfg.sliding_window and cfg.global_attn_layers:
        # generic SWA/global interleave (same mechanism as hybrid)
        groups = []
        glob = set(cfg.global_attn_layers)
        i = 0
        while i < cfg.num_layers:
            if i in glob:
                groups.append((1, BlockKind(attn="full", ffn="dense")))
                i += 1
            else:
                j = i
                while j < cfg.num_layers and j not in glob:
                    j += 1
                groups.append((j - i, BlockKind(attn="swa", ffn="dense")))
                i = j
        return groups
    return [(cfg.num_layers, BlockKind(attn=attn, ffn="dense"))]


# ------------------------------------------------------------------ block init
def block_init(rng, cfg: ModelConfig, kind: BlockKind, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p: dict = {}
    if kind.attn != "none":
        p["norm1"] = L.norm_init(cfg.d_model, cfg.norm_type, dtype)
        if kind.attn == "mla":
            p["attn"] = A.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = A.gqa_init(ks[0], cfg, dtype)
    if kind.ssm:
        if "norm1" not in p:
            p["norm1"] = L.norm_init(cfg.d_model, cfg.norm_type, dtype)
        p["ssm"] = M.mamba_init(ks[1], cfg, dtype)
        if kind.attn != "none":   # hybrid: per-branch output norms (hymba)
            p["attn_out_norm"] = L.norm_init(cfg.d_model, cfg.norm_type, dtype)
            p["ssm_out_norm"] = L.norm_init(cfg.d_model, cfg.norm_type, dtype)
    if kind.ffn != "none":
        p["norm2"] = L.norm_init(cfg.d_model, cfg.norm_type, dtype)
        if kind.ffn == "moe":
            p["ffn"] = F.moe_init(ks[2], cfg, dtype)
        else:
            p["ffn"] = F.ffn_init(ks[2], cfg, dtype=dtype)
    return p


def block_cache_init(cfg: ModelConfig, kind: BlockKind, batch: int,
                     max_len: int, dtype=jnp.bfloat16, kv_quant=None):
    quantized = kv_quant is not None and kv_quant.quantized
    if quantized and (kind.attn != "full" or kind.ssm):
        raise ValueError(
            f"quantized KV (kv_quant) supports full-attention GQA blocks "
            f"only, got attn={kind.attn!r} ssm={kind.ssm}")
    c: dict = {}
    if kind.attn == "mla":
        c["attn"] = A.init_mla_cache(cfg, batch, max_len, dtype)
    elif kind.attn == "swa":
        c["attn"] = A.init_gqa_cache(cfg, batch, max_len,
                                     window=cfg.sliding_window,
                                     num_sink=cfg.meta_tokens, dtype=dtype)
    elif kind.attn == "full":
        c["attn"] = A.init_gqa_cache(cfg, batch, max_len, dtype=dtype,
                                     kv_quant=kv_quant)
    if kind.ssm:
        c["ssm"] = M.init_mamba_cache(cfg, batch, dtype)
    return c


def block_paged_cache_init(cfg: ModelConfig, kind: BlockKind, num_pages: int,
                           page_size: int, dtype=jnp.bfloat16, kv_quant=None):
    """Paged-layout cache for one block: (num_pages + 1, page_size, Hkv, D)
    physical pools (page 0 is the null page — see serving/kv_cache.py), plus
    (num_pages + 1, page_size, Hkv) per-token scale pools when ``kv_quant``
    stores int8.  Only homogeneous full-attention stacks support paging."""
    if kind.attn != "full" or kind.ssm:
        raise ValueError(
            f"paged cache layout supports full-attention blocks only, got "
            f"attn={kind.attn!r} ssm={kind.ssm}")
    shape = (num_pages + 1, page_size, cfg.num_kv_heads, cfg.head_dim)
    if kv_quant is not None and kv_quant.quantized:
        if kv_quant.granularity != "token":
            raise ValueError(
                "the fused paged write path is per-token; per-page scales "
                "are served by the PagedCache data-path API only")
        sdt = kv_quant.scale_jnp_dtype
        return {"attn": {"k_pages": jnp.zeros(shape, jnp.int8),
                         "v_pages": jnp.zeros(shape, jnp.int8),
                         "k_scales": jnp.zeros(shape[:-1], sdt),
                         "v_scales": jnp.zeros(shape[:-1], sdt)}}
    return {"attn": {"k_pages": jnp.zeros(shape, dtype),
                     "v_pages": jnp.zeros(shape, dtype)}}


def group_paged_cache_init(cfg, kind, count, num_pages, page_size,
                           dtype=jnp.bfloat16, kv_quant=None):
    one = block_paged_cache_init(cfg, kind, num_pages, page_size, dtype,
                                 kv_quant)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one)


# ----------------------------------------------------------------- block apply
def block_apply(p, x, *, cfg: ModelConfig, kind: BlockKind,
                kernels=L.DEFAULT_KERNELS, positions=None, cache=None,
                seq_lens=None, num_sink: int = 0, block_tables=None,
                write_lens=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if kind.attn != "none" or kind.ssm:
        h = L.apply_norm(p["norm1"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)
        branch_out = None
        if kind.attn == "mla":
            ao, ac = A.mla_apply(p["attn"], h, cfg=cfg, kernels=kernels,
                                 positions=positions,
                                 cache=cache.get("attn") if cache else None,
                                 seq_lens=seq_lens)
            branch_out = ao
            if ac is not None:
                new_cache["attn"] = ac
        elif kind.attn in ("full", "swa"):
            window = cfg.sliding_window if kind.attn == "swa" else 0
            ao, ac = A.gqa_apply(p["attn"], h, cfg=cfg, kernels=kernels,
                                 positions=positions,
                                 cache=cache.get("attn") if cache else None,
                                 seq_lens=seq_lens, window=window,
                                 causal=not cfg.is_encoder, num_sink=num_sink,
                                 block_tables=block_tables,
                                 write_lens=write_lens)
            branch_out = ao
            if ac is not None:
                new_cache["attn"] = ac
        if kind.ssm:
            so, sc = M.mamba_apply(p["ssm"], h, cfg=cfg, kernels=kernels,
                                   cache=cache.get("ssm") if cache else None)
            if sc is not None:
                new_cache["ssm"] = sc
            if branch_out is not None:   # hybrid: mean of normalized branches
                branch_out = 0.5 * (
                    L.apply_norm(p["attn_out_norm"], branch_out,
                                 norm_type=cfg.norm_type, eps=cfg.norm_eps)
                    + L.apply_norm(p["ssm_out_norm"], so,
                                   norm_type=cfg.norm_type, eps=cfg.norm_eps))
            else:
                branch_out = so
        x = x + branch_out

    if kind.ffn != "none":
        h = L.apply_norm(p["norm2"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)
        if kind.ffn == "moe":
            moe_fn = F.moe_apply_ep if cfg.moe_impl == "ep" else F.moe_apply
            fo, a = moe_fn(p["ffn"], h, cfg=cfg, kernels=kernels)
            aux = aux + a
        else:
            fo = F.ffn_apply(p["ffn"], h, cfg=cfg, kernels=kernels)
        x = x + fo
    return x, new_cache, aux


# ----------------------------------------------------------------- group level
def group_init(rng, cfg: ModelConfig, count: int, kind: BlockKind,
               dtype=jnp.float32):
    rngs = jax.random.split(rng, count)
    return jax.vmap(lambda r: block_init(r, cfg, kind, dtype))(rngs)


def group_cache_init(cfg, kind, count, batch, max_len, dtype=jnp.bfloat16,
                     kv_quant=None):
    one = block_cache_init(cfg, kind, batch, max_len, dtype, kv_quant)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one)


def group_apply(stack, x, *, cfg: ModelConfig, kind: BlockKind, count: int,
                kernels=L.DEFAULT_KERNELS, positions=None, cache=None,
                seq_lens=None, num_sink: int = 0, remat: str | None = None,
                block_tables=None, write_lens=None):
    """Scan a homogeneous group of ``count`` blocks. Returns (x, new_cache, aux)."""
    remat = remat if remat is not None else cfg.remat

    def body_fn(p, x, c):
        x = L.constrain_act(x)   # keep scan carry / saved residuals sharded
        return block_apply(p, x, cfg=cfg, kind=kind, kernels=kernels,
                           positions=positions, cache=c, seq_lens=seq_lens,
                           num_sink=num_sink, block_tables=block_tables,
                           write_lens=write_lens)

    if remat == "full":
        body_fn = jax.checkpoint(body_fn)
    elif remat == "dots":
        body_fn = jax.checkpoint(
            body_fn, policy=jax.checkpoint_policies.checkpoint_dots)

    if not cfg.scan_layers:
        caches, auxes = [], jnp.zeros((), jnp.float32)
        for i in range(count):
            p_i = jax.tree_util.tree_map(lambda a: a[i], stack)
            c_i = (jax.tree_util.tree_map(lambda a: a[i], cache)
                   if cache is not None else None)
            with L.name_scope(f"layer{i}"):
                x, nc, a = body_fn(p_i, x, c_i)
            caches.append(nc)
            auxes = auxes + a
        new_cache = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
                     if cache is not None else None)
        return x, new_cache, auxes

    def scan_body(carry, xs):
        x, aux = carry
        p, c = xs
        x, nc, a = body_fn(p, x, c)
        return (x, aux + a), nc

    (x, aux), new_cache = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), (stack, cache))
    return x, new_cache, aux
