"""Shared layer primitives: linear (fp + GPTQ dispatch + calibration capture),
norms, RoPE / M-RoPE, embeddings.

Params are plain nested dicts of jnp arrays; every function is pure. The only
impurity is the module-level calibration capture context, used exclusively by
the (unjitted, unrolled) GPTQ calibration pass.
"""
from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp

from repro.core.gptq import QuantizedLinear, accumulate_hessian
from repro.core.opt_strategies import KernelStrategy, OPT4GPTQ
from repro.kernels import ops as kops


# --------------------------------------------------------- calibration capture
@dataclasses.dataclass
class CaptureContext:
    hessians: dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    counts: dict[str, int] = dataclasses.field(default_factory=dict)
    active: bool = False

    def add(self, name: str, x: jnp.ndarray):
        self.hessians[name] = accumulate_hessian(self.hessians.get(name), x)
        self.counts[name] = self.counts.get(name, 0) + int(
            x.reshape(-1, x.shape[-1]).shape[0])


_CAPTURE = CaptureContext()
_NAME_STACK: list[str] = []


class name_scope:
    """Qualifies capture names per layer (calibration runs unscanned/unjitted)."""

    def __init__(self, name: str):
        self.name = name

    def __enter__(self):
        _NAME_STACK.append(self.name)
        return self

    def __exit__(self, *exc):
        _NAME_STACK.pop()
        return False


def qualified(name: str) -> str:
    return ".".join(_NAME_STACK + [name]) if _NAME_STACK else name


def capture_context() -> CaptureContext:
    return _CAPTURE


class capture_hessians:
    """with capture_hessians() as ctx: model.apply(...)  (unjitted only)."""

    def __enter__(self):
        global _CAPTURE
        _CAPTURE = CaptureContext(active=True)
        return _CAPTURE

    def __exit__(self, *exc):
        _CAPTURE.active = False
        return False


# -------------------------------------------------- activation sharding hooks
# Set by launch code (trace-time static): constrains (B, S, D) activations so
# GSPMD shards scan carries / saved residuals instead of replicating them, and
# (B, S, H, D) / (B, H, Sq, Sk) attention tensors so logits shard over heads
# (GSPMD pads when H doesn't divide the axis — e.g. hymba's 25 heads / 16).
_ACT_SPEC = None      # (B, S, D)
_HEADS_SPEC = None    # (B, S, H, D)
_LOGITS_SPEC = None   # (B, H, Sq, Sk)
_MOE_SPEC = None      # (E, C, d/f) dispatch buffers


def set_act_sharding(spec, heads_spec=None, logits_spec=None, moe_spec=None):
    """specs: jax.sharding.PartitionSpec or None."""
    global _ACT_SPEC, _HEADS_SPEC, _LOGITS_SPEC, _MOE_SPEC
    _ACT_SPEC = spec
    _HEADS_SPEC = heads_spec
    _LOGITS_SPEC = logits_spec
    _MOE_SPEC = moe_spec


def constrain_moe(x):
    if _MOE_SPEC is None or x.ndim != 4:
        return x
    return jax.lax.with_sharding_constraint(x, _MOE_SPEC)


# shard_map expert-parallel context: (mesh, fsdp_axis, model_axis, batch_axes)
_MOE_EP = None


def set_moe_ep(mesh, fsdp_axis: str, model_axis: str, batch_axes):
    global _MOE_EP
    _MOE_EP = None if mesh is None else (mesh, fsdp_axis, model_axis,
                                         batch_axes)


def moe_ep_context():
    return _MOE_EP


# shard_map tensor-parallel epilogue (serving/parallel.py, DESIGN.md §17):
# armed at trace time inside the shard_map body.  When set, row-parallel
# linears (wo / w_down) hold K-shards, so their partial matmul outputs are
# completed with a psum over the named TP axis; unset (the default), the
# epilogue is the identity and single-device traces are untouched.
_TP_AXIS = None


class tp_epilogue:
    """``with L.tp_epilogue(axis): model.apply(...)`` — inside a shard_map
    body only; nests/restores like a dynamic scope."""

    def __init__(self, axis: str):
        self.axis = axis

    def __enter__(self):
        global _TP_AXIS
        self._prev = _TP_AXIS
        _TP_AXIS = self.axis
        return self

    def __exit__(self, *exc):
        global _TP_AXIS
        _TP_AXIS = self._prev
        return False


def tp_all_reduce(y):
    """Row-parallel all-reduce epilogue: psum when a TP axis is armed."""
    if _TP_AXIS is None:
        return y
    return jax.lax.psum(y, _TP_AXIS)


def constrain_act(x):
    if _ACT_SPEC is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SPEC)


def constrain_heads(x):
    if _HEADS_SPEC is None or x.ndim != 4:
        return x
    return jax.lax.with_sharding_constraint(x, _HEADS_SPEC)


def constrain_logits(x):
    if _LOGITS_SPEC is None or x.ndim != 4:
        return x
    return jax.lax.with_sharding_constraint(x, _LOGITS_SPEC)


# ------------------------------------------------------------------ kernel cfg
LANE = 128   # TPU lane width: last-dim tiling unit for VMEM tiles


class CacheLayout(str, enum.Enum):
    """Serving KV-cache layout (DESIGN.md §2/§10).

    SLOT  : contiguous (B, max_len, ...) per-slot cache — the TPU-idiomatic
            default; shape-stable jitted decode.
    PAGED : block-table pages over a shared physical pool — the vLLM
            PagedAttention layout; decode runs the Pallas paged-attention
            kernel (``kernels/paged_attention.py``).
    """
    SLOT = "slot"
    PAGED = "paged"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """How quantized linears and the serving cache execute (threaded through
    model apply fns).

    ``block_sizes`` is a concrete (bm, bn, bk) tuple, ``None`` for the kernel
    defaults, or ``"auto"`` to consult the per-shape autotuner cache
    (``kernels/autotune.py`` — tuned once per (M, K, N, group, strategy) key,
    persisted to JSON).  ``cache_layout`` selects the serving cache layout
    (``Engine(cache=...)`` defaults to it); ``paged_attention_impl`` /
    ``paged_prefill_impl`` pick the paged decode / prefill hot paths —
    ``"kernel"`` (the Pallas kernels, interpret-mode on CPU) or ``"ref"``
    (the jnp gather oracles in ``kernels/ref.py``, which materialize a
    contiguous KV copy — debugging and the bench's gather-vs-kernel
    comparison only).

    ``q_chunk`` bounds the query rows per grid step of the chunked
    ``paged_prefill`` kernel (the VMEM query tile is (q_chunk·rep, D)).
    ``None`` keeps the historical 128; ``"auto"`` consults the autotuner
    cache (co-tuned with the engine's step token budget); a concrete value
    must be a positive multiple of the 128-wide TPU lane."""
    strategy: KernelStrategy = OPT4GPTQ
    use_pallas: bool = False          # False: jnp ref path (CPU / dry-run)
    block_sizes: tuple[int, int, int] | str | None = None
    cache_layout: str = CacheLayout.SLOT
    paged_attention_impl: str = "kernel"
    paged_prefill_impl: str = "kernel"
    q_chunk: int | str | None = None

    def __post_init__(self):
        qc = self.q_chunk
        if qc is None or qc == "auto":
            return
        if not isinstance(qc, int) or isinstance(qc, bool) or qc <= 0 \
                or qc % LANE != 0:
            raise ValueError(
                f"q_chunk must be a positive multiple of the {LANE}-wide "
                f"lane (or 'auto'), got {qc!r}")


DEFAULT_KERNELS = KernelConfig()


# ---------------------------------------------------------------------- linear
def linear_init(rng, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(rng, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jnp.ndarray, *, name: str = "",
           kernels: KernelConfig = DEFAULT_KERNELS) -> jnp.ndarray:
    """Apply a linear layer; dispatches on param type (fp vs GPTQ-quantized)."""
    if _CAPTURE.active and name:
        _CAPTURE.add(qualified(name), x)
    w = p["w"]
    if isinstance(w, QuantizedLinear):
        y = kops.gptq_linear(w, x, strategy=kernels.strategy,
                             use_pallas=kernels.use_pallas,
                             block_sizes=kernels.block_sizes)
    else:
        y = jnp.dot(x, w.astype(x.dtype))
    if "b" in p and p["b"] is not None:
        y = y + p["b"].astype(y.dtype)
    return y


# ----------------------------------------------------------------------- norms
def norm_init(d: int, norm_type: str = "rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x: jnp.ndarray, *, norm_type: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """qk-norm: RMSNorm over the head_dim of (..., H, D)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: tuple[int, ...] | None = None) -> jnp.ndarray:
    """x: (B, S, H, D). positions: (B, S) int32, or (3, B, S) for M-RoPE
    (temporal/height/width sections, qwen2-vl)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                    # (D/2,)
    if mrope_sections is not None and positions.ndim == 3:
        # split the D/2 frequencies into t/h/w sections, each using its own pos
        secs = mrope_sections
        assert sum(secs) == d // 2, (secs, d)
        pos_parts = []
        start = 0
        for i, s in enumerate(secs):
            pos_parts.append(jnp.broadcast_to(positions[i][..., None],
                                              positions.shape[1:] + (s,)))
            start += s
        pos = jnp.concatenate(pos_parts, axis=-1)                 # (B, S, D/2)
        ang = pos.astype(jnp.float32) * inv[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions[..., None].astype(jnp.float32) * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]                             # (B, S, 1, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ embeddings
def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return {"embedding": jax.random.normal(rng, (vocab, d), dtype) * 0.02}


def embed_lookup(p, ids: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(p["embedding"], ids, axis=0).astype(dtype)


def embed_logits(p, x: jnp.ndarray) -> jnp.ndarray:
    """Tied output head: logits = x @ E^T (f32 for stability)."""
    return jnp.dot(x.astype(jnp.float32),
                   p["embedding"].astype(jnp.float32).T)


# ------------------------------------------------------------------ activations
def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def squared_relu(x: jnp.ndarray) -> jnp.ndarray:
    r = jax.nn.relu(x)
    return r * r
