"""Feed-forward variants: SwiGLU, squared-ReLU (Nemotron), and MoE with
sort-based capacity-padded dispatch (TPU-idiomatic EP; active-FLOPs-exact for
the roofline — no dense all-experts compute, no O(T^2) one-hot einsum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


# ---------------------------------------------------------------------- dense
def ffn_init(rng, cfg: ModelConfig, d_ff: int | None = None, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": L.linear_init(ks[0], cfg.d_model, d_ff, dtype=dtype),
            "w_up": L.linear_init(ks[1], cfg.d_model, d_ff, dtype=dtype),
            "w_down": L.linear_init(ks[2], d_ff, cfg.d_model, dtype=dtype),
        }
    return {  # sq_relu: up + down only
        "w_up": L.linear_init(ks[1], cfg.d_model, d_ff, dtype=dtype),
        "w_down": L.linear_init(ks[2], d_ff, cfg.d_model, dtype=dtype),
    }


def ffn_apply(p, x, *, cfg: ModelConfig, kernels=L.DEFAULT_KERNELS):
    if cfg.act == "swiglu":
        h = L.swiglu(L.linear(p["w_gate"], x, name="w_gate", kernels=kernels),
                     L.linear(p["w_up"], x, name="w_up", kernels=kernels))
    else:
        h = L.squared_relu(L.linear(p["w_up"], x, name="w_up", kernels=kernels))
    # row-parallel epilogue (DESIGN.md §17): w_down's K axis (d_ff) is the
    # sharded gate/up output under tensor-parallel serving — psum completes
    # the partial matmul; identity when no TP axis is armed
    return L.tp_all_reduce(
        L.linear(p["w_down"], h, name="w_down", kernels=kernels))


def _expert_weights(w, dtype):
    """(E, K, N) expert tensor; GPTQ-quantized experts dequantize on the fly
    (int4 reads — the HBM traffic the roofline should see)."""
    from repro.core.gptq import QuantizedLinear
    from repro.kernels.ref import dequant_ref
    if isinstance(w, QuantizedLinear):
        dq = jax.vmap(lambda qw, s, qz: dequant_ref(
            qw, s, qz, group_size=w.group_size, dtype=dtype))
        return dq(w.qweight, w.scales, w.qzeros)
    return w.astype(dtype)


# ------------------------------------------------------------------------ MoE
def moe_init(rng, cfg: ModelConfig, dtype=jnp.float32):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(rng, 5)
    scale = d ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), dtype) * scale},
        "experts": {
            "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * scale,
            "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * scale,
            "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * (f ** -0.5),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = ffn_init(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.num_shared_experts,
                               dtype=dtype)
    return p


def moe_apply(p, x, *, cfg: ModelConfig, kernels=L.DEFAULT_KERNELS):
    """Returns (y, aux_loss). Sort-based dispatch:

      1. router softmax -> top-k experts per token
      2. rank each (token, k) pair within its expert via argsort
      3. scatter into (G, E, C, d) capacity-padded buffers (overflow dropped)
      4. batched expert SwiGLU einsums (active FLOPs only)
      5. gather back, weight by gate prob, sum over k

    ``cfg.moe_dispatch_groups`` (G) makes the rank/scatter LOCAL to each group
    of T/G tokens: with G = dp shards and the group dim batch-sharded, the
    scatter never crosses data-parallel shards — GSPMD emits no cross-shard
    buffer all-reduce (the collective-term fix measured in EXPERIMENTS.md
    §Perf) and each expert gets per-group capacity, matching how real EP
    implementations drop tokens per-rank.
    """
    b, s, d = x.shape
    e, topk = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    g = cfg.moe_dispatch_groups if t % max(cfg.moe_dispatch_groups, 1) == 0 else 1
    tl = t // g                                               # tokens per group
    xt = x.reshape(t, d)

    logits = L.linear(p["router"], xt.astype(jnp.float32), name="router",
                      kernels=L.DEFAULT_KERNELS)              # router never quantized
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate, expert_idx = jax.lax.top_k(probs, topk)             # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                        # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
    aux = cfg.router_aux_loss * e * jnp.sum(me * ce)

    cap = int(cfg.capacity_factor * topk * tl / e) + 1
    flat_e = expert_idx.reshape(g, tl * topk)                           # (G, Tl*k)
    # rank of each assignment within (group, expert), stable in token order
    order = jnp.argsort(flat_e, axis=1, stable=True)
    ranks = jnp.broadcast_to(jnp.arange(tl * topk)[None], flat_e.shape)
    rank_in_order = jnp.zeros_like(order).at[
        jnp.arange(g)[:, None], order].set(ranks)
    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=e))(flat_e)    # (G, E)
    starts = jnp.cumsum(counts, axis=1) - counts                        # (G, E)
    slot = rank_in_order - jnp.take_along_axis(starts, flat_e, axis=1)  # (G, Tl*k)
    valid = slot < cap
    slot_c = jnp.where(valid, slot, cap - 1)

    src = jnp.repeat(xt.reshape(g, tl, d)[:, :, None, :], topk,
                     axis=2).reshape(g, tl * topk, d)
    buf = jnp.zeros((g, e, cap, d), x.dtype)
    gi = jnp.arange(g)[:, None]
    buf = buf.at[gi, flat_e, slot_c].set(
        jnp.where(valid[..., None], src, 0), mode="drop")
    buf = L.constrain_moe(buf)   # (G, E, C, d): dp x EP sharding

    we = {k: _expert_weights(v, x.dtype) for k, v in p["experts"].items()}
    h = L.constrain_moe(
        L.swiglu(jnp.einsum("gecd,edf->gecf", buf, we["w_gate"]),
                 jnp.einsum("gecd,edf->gecf", buf, we["w_up"])))
    out_buf = L.constrain_moe(jnp.einsum("gecf,efd->gecd", h, we["w_down"]))

    gathered = out_buf[gi, flat_e, slot_c]                              # (G, Tl*k, d)
    gathered = jnp.where(valid[..., None], gathered, 0)
    y = (gathered.reshape(t, topk, d)
         * gate[..., None].astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        y = y + ffn_apply(p["shared"], xt, cfg=cfg, kernels=kernels)
    return y.reshape(b, s, d), aux


# --------------------------------------------------- shard_map expert parallel
def moe_apply_ep(p, x, *, cfg: ModelConfig, kernels=L.DEFAULT_KERNELS):
    """True EP: per-shard capacity buckets exchanged with ``all_to_all`` over
    the model axis. Collective cost per layer = 2 x bucket bytes (~tokens*d),
    vs the GSPMD-auto einsum path's full-buffer mask+all-reduce (measured 40x
    wire reduction on deepseek-v2 train — EXPERIMENTS.md §Perf cell A).

    Requirements: EP context set (layers.set_moe_ep), E % tp == 0, unquantized
    expert weights (training path). Falls back to ``moe_apply`` otherwise.
    """
    from repro.core.gptq import QuantizedLinear
    ctx = L.moe_ep_context()
    e, topk = cfg.num_experts, cfg.num_experts_per_tok
    if ctx is None or isinstance(p["experts"]["w_gate"], QuantizedLinear):
        return moe_apply(p, x, cfg=cfg, kernels=kernels)
    mesh, fsdp_ax, model_ax, batch_axes = ctx
    tp = mesh.shape[model_ax]
    if e % tp != 0:
        return moe_apply(p, x, cfg=cfg, kernels=kernels)
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e_loc = e // tp
    f = cfg.moe_d_ff

    # router outside shard_map (tiny output; weights follow their own specs)
    logits = L.linear(p["router"], x.astype(jnp.float32), name="router")
    probs = jax.nn.softmax(logits, axis=-1)                       # (B, S, E)
    gate, expert_idx = jax.lax.top_k(probs, topk)                 # (B, S, k)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], e), axis=(0, 1))
    aux = cfg.router_aux_loss * e * jnp.sum(me * ce)

    seq_shardable = s % tp == 0
    seq_ax = model_ax if seq_shardable else None
    bspec = batch_axes or None

    def body(xb, gateb, idxb, wg, wu, wd):
        # xb: (B/dp, S/tp, d); wg/wu: (e_loc, d/fsdp, f); wd: (e_loc, f, d/fsdp)
        wg = jax.lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_ax, axis=2, tiled=True)
        bl, sl, _ = xb.shape
        tl = bl * sl
        xt = xb.reshape(tl, d)
        ib = idxb.reshape(tl * topk)                              # global e id
        gb = gateb.reshape(tl * topk)
        tgt = ib // e_loc                                         # owner rank
        le = ib % e_loc
        cap = int(cfg.capacity_factor * topk * tl / e) + 1

        order = jnp.argsort(ib, stable=True)
        rank_in = jnp.zeros_like(order).at[order].set(jnp.arange(tl * topk))
        counts = jnp.bincount(ib, length=e)
        starts = jnp.cumsum(counts) - counts
        slot = rank_in - starts[ib]
        valid = slot < cap
        slot_c = jnp.where(valid, slot, cap - 1)

        src = jnp.repeat(xt[:, None, :], topk, axis=1).reshape(tl * topk, d)
        buckets = jnp.zeros((tp, e_loc, cap, d), x.dtype)
        buckets = buckets.at[tgt, le, slot_c].set(
            jnp.where(valid[:, None], src, 0), mode="drop")
        # exchange: rank i's bucket j -> rank j (the EP all-to-all)
        recv = jax.lax.all_to_all(buckets, model_ax, split_axis=0,
                                  concat_axis=0, tiled=True)
        toks = jnp.moveaxis(recv, 0, 1).reshape(e_loc, tp * cap, d)
        h = L.swiglu(jnp.einsum("ecd,edf->ecf", toks, wg.astype(x.dtype)),
                     jnp.einsum("ecd,edf->ecf", toks, wu.astype(x.dtype)))
        out = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))
        outb = jnp.moveaxis(out.reshape(e_loc, tp, cap, d), 1, 0)
        back = jax.lax.all_to_all(outb, model_ax, split_axis=0,
                                  concat_axis=0, tiled=True)
        gathered = back[tgt, le, slot_c]
        gathered = jnp.where(valid[:, None], gathered, 0)
        y = (gathered.reshape(tl, topk, d) * gb.reshape(tl, topk)[..., None]
             ).sum(axis=1)
        return y.reshape(bl, sl, d)

    we = p["experts"]
    in_specs = (P(bspec, seq_ax, None), P(bspec, seq_ax, None),
                P(bspec, seq_ax, None),
                P(model_ax, fsdp_ax, None), P(model_ax, fsdp_ax, None),
                P(model_ax, None, fsdp_ax))
    # jax >= 0.5 exposes jax.shard_map; older versions only have the
    # experimental module, and spell the no-replication-check kwarg check_rep
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        shard = sm(body, mesh=mesh, in_specs=in_specs,
                   out_specs=P(bspec, seq_ax, None), check_vma=False)
    except TypeError:
        shard = sm(body, mesh=mesh, in_specs=in_specs,
                   out_specs=P(bspec, seq_ax, None), check_rep=False)
    y = shard(x, gate, expert_idx,
              we["w_gate"].astype(x.dtype), we["w_up"].astype(x.dtype),
              we["w_down"].astype(x.dtype))
    if "shared" in p:
        y = y + ffn_apply(p["shared"], x, cfg=cfg, kernels=kernels)
    return y, aux
