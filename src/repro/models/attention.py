"""Attention variants: GQA (bias / qk-norm / sliding-window / M-RoPE) and
DeepSeek-style MLA (compressed KV cache, absorbed decode path).

All paths funnel into one primitive, ``attend``: grouped-GQA einsums (no
head-repetition materialization) with a position-based mask and **query
chunking** (`lax.map` + checkpoint) so (B, H, Sq, Sk) logits never exceed a
chunk — the jnp analogue of flash attention, mandatory for 32k prefill /
train_4k backward memory.

Cache layouts (DESIGN.md §2/§10):
  full attention : slot — k/v (B, max_len, Hkv, D), write at seq_lens via
                   scatter; or paged — k/v pools (pages, page_size, Hkv, D)
                   addressed through a per-sequence device block table
                   (decode AND prefill run kernels/paged_attention.py —
                   no gathered KV copy exists anywhere on the paged path)
  sliding window : ring buffers (B, window + num_sink, Hkv, D); the first
                   num_sink slots pin attention sinks (hymba meta tokens)
  MLA            : compressed (B, max_len, kv_lora + rope_dim)

Quantized KV (DESIGN.md §12): with ``kv_quant`` the full-attention caches
store int8 payloads plus parallel per-token symmetric scale arrays
(``k_scale``/``v_scale`` slot, ``k_scales``/``v_scales`` paged); writes
quantize in the same fused scatter and reads rescale inside the attention
math (``attend``'s grouped path folds K scales into the logits and V scales
into the probabilities; the paged decode kernel dequantizes in VMEM) — a
floating-point copy of the cache is never materialized on the hot path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import paged_attention as PA
from repro.kernels import ref as KR
from repro.models import layers as L
from repro.serving import kv_quant as KQ

Q_CHUNK = 2048          # max query rows per logits block
NEG_INF = -1e30


def _mask(qpos, kpos, valid, *, causal: bool, window: int, num_sink: int):
    """qpos: (B, Sq); kpos: (B, Sk) absolute key positions; valid: (B, Sk) or
    None. Returns (B, Sq, Sk) boolean."""
    qp = qpos[:, :, None]
    kp = kpos[:, None, :]
    m = jnp.ones(qp.shape[:2] + (kpos.shape[1],), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= (kp > qp - window) | (kp < num_sink)
    if valid is not None:
        m &= valid[:, None, :]
    return m


def _attend_block(q, k, v, qpos, kpos, valid, *, causal, window, num_sink,
                  scale, grouped: bool = False, k_scale=None, v_scale=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, Dk/Dv).

    Train/prefill (``grouped=False``): K/V repeated to H heads so logits shard
    over the (padded) head axis — see layers.set_act_sharding.
    Decode (``grouped=True``): grouped-GQA einsum keeps the K/V cache in its
    native layout — no repeat, no cache resharding (§Perf cell B iteration 4).
    All einsums take bf16 operands with f32 accumulation — an f32 copy of the
    (large) K/V cache is never materialized (§Perf cell B iteration 2).

    Quantized KV (``k_scale``/``v_scale``: (B, Sk, Hkv) per-token symmetric
    scales over int8 k/v): the grouped path folds the K scales into the
    logits after the QK product and the V scales into the probabilities
    before the PV product — mathematically identical to dequantizing the
    cache, without ever building the fp copy."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    m = _mask(qpos, kpos, valid, causal=causal, window=window,
              num_sink=num_sink)
    # the grouped einsum also hosts the fused-dequant path at rep == 1
    if grouped and (rep > 1 or k_scale is not None):
        qg = q.reshape(b, sq, hkv, rep, d)
        kk = k if k_scale is None else k.astype(jnp.float32)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kk,
                            preferred_element_type=jnp.float32) * scale
        if k_scale is not None:       # (B, Sk, Hkv) -> (B, Hkv, 1, 1, Sk)
            logits = logits * k_scale.astype(jnp.float32).transpose(
                0, 2, 1)[:, :, None, None, :]
        logits = jnp.where(m[:, None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        if v_scale is not None:
            pv = p * v_scale.astype(jnp.float32).transpose(
                0, 2, 1)[:, :, None, None, :]
            out = jnp.einsum("bgrqk,bkgd->bqgrd", pv,
                             v.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        else:
            out = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                             preferred_element_type=jnp.float32)
        return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)
    if k_scale is not None:
        # prefill route: dequantize up front — the same order of extra fp
        # bytes this branch already spends on GQA head repetition
        k = KQ.dequantize(k, k_scale, dtype=q.dtype)
        v = KQ.dequantize(v, v_scale, dtype=q.dtype)
    if rep > 1:
        k = L.constrain_heads(jnp.repeat(k, rep, axis=2))
        v = L.constrain_heads(jnp.repeat(v, rep, axis=2))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = L.constrain_logits(logits)
    logits = jnp.where(m[:, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attend(q, k, v, *, qpos, kpos=None, valid=None, causal=True, window=0,
           num_sink=0, scale=None, chunk=Q_CHUNK, grouped=False,
           k_scale=None, v_scale=None):
    """Unified masked attention with query chunking.

    q (B,Sq,H,D); k,v (B,Sk,Hkv,·); qpos (B,Sq) absolute query positions;
    kpos (B,Sk) absolute key positions (default arange); valid (B,Sk) marks
    live cache slots; k_scale/v_scale (B,Sk,Hkv) mark k/v as int8 payloads
    with per-token symmetric dequant scales (fused — see _attend_block)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kpos is None:
        kpos = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    fn = functools.partial(_attend_block, causal=causal, window=window,
                           num_sink=num_sink, scale=scale, grouped=grouped,
                           k_scale=k_scale, v_scale=v_scale)
    if sq <= chunk or sq % chunk != 0:
        return fn(q, k, v, qpos, kpos, valid)
    nc = sq // chunk
    qs = jnp.moveaxis(q.reshape(b, nc, chunk, h, d), 1, 0)
    ps = jnp.moveaxis(qpos.reshape(b, nc, chunk), 1, 0)

    def one(args):
        qc, pc = args
        return fn(qc, k, v, pc, kpos, valid)

    outs = jax.lax.map(jax.checkpoint(one), (qs, ps))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, v.shape[-1])


# ------------------------------------------------------------------------- GQA
def gqa_init(rng, cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": L.linear_init(ks[0], d, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.linear_init(ks[1], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.linear_init(ks[2], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.linear_init(ks[3], cfg.num_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _resolve_q_chunk(kernels, chunk: int, s: int, cfg, page_size: int) -> int:
    """Paged-prefill query tile height (ISSUE 10 satellite).  ``None`` keeps
    the historical 128; a concrete int was lane-validated by ``KernelConfig``;
    ``"auto"`` consults the autotuner cache — shapes are static at trace
    time, so the lookup (which times concrete synthetic arrays) runs
    host-side even under an outer jit trace."""
    qc = getattr(kernels, "q_chunk", None)
    if qc == "auto":
        from repro.kernels import autotune as AT
        qc = AT.get_q_chunk(s, cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim, page_size)
    return min(chunk, qc or 128)


def gqa_apply(p, x, *, cfg: ModelConfig, kernels=L.DEFAULT_KERNELS,
              positions=None, cache=None, seq_lens=None, window: int = 0,
              causal: bool = True, num_sink: int = 0, block_tables=None,
              write_lens=None):
    """Returns (out, new_cache)."""
    b, s, d = x.shape
    hd = cfg.head_dim
    q = L.linear(p["wq"], x, name="wq", kernels=kernels).reshape(b, s, cfg.num_heads, hd)
    k = L.linear(p["wk"], x, name="wk", kernels=kernels).reshape(b, s, cfg.num_kv_heads, hd)
    v = L.linear(p["wv"], x, name="wv", kernels=kernels).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = L.rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(s)[None, :].astype(jnp.int32) + jnp.zeros((b, 1), jnp.int32)
    q = L.constrain_heads(L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections))
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    chunk = cfg.attn_q_chunk
    if cache is None:
        qpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        out = attend(q, k, v, qpos=qpos, causal=causal and not cfg.is_encoder,
                     window=window, num_sink=num_sink, chunk=chunk)
        new_cache = None
    elif "k_pages" in cache:
        # Paged layout (DESIGN.md §10/§13): K/V pages of a shared physical
        # pool addressed through the per-sequence device block table.  Decode
        # runs the Pallas paged-attention kernel; prefill runs the chunked
        # paged-prefill kernel directly over the pool — no path here ever
        # materializes a gathered KV copy (``kernels.paged_*_impl = "ref"``
        # routes to the jnp oracles in ``kernels/ref.py``, which do gather —
        # debugging only).  Right-padded (bucketed) prefill passes
        # ``write_lens`` — padded positions' writes are routed to the null
        # page so they never corrupt real pages; positions past the block
        # table (an overrunning sequence) are null-routed too instead of
        # aliasing into the last table column's live page.
        assert block_tables is not None, "paged cache requires block_tables"
        assert window == 0 and num_sink == 0, "paged layout is full-attn only"
        kp, vp = cache["k_pages"], cache["v_pages"]
        ksc, vsc = cache.get("k_scales"), cache.get("v_scales")
        ps = kp.shape[1]
        tpos = seq_lens[:, None] + jnp.arange(s)[None, :]          # (B, S) abs
        # out-of-range logical pages (an overrunning sequence) fill with the
        # null page instead of aliasing into the last table column
        pages = jnp.take_along_axis(block_tables, tpos // ps, axis=1,
                                    mode="fill", fill_value=0)
        if write_lens is not None:                                 # (B,) real
            pages = jnp.where(jnp.arange(s)[None, :] < write_lens[:, None],
                              pages, 0)                            # null page
        offs = tpos % ps
        # one scatter per pool per layer-call: every new token's KV lands in
        # its (page, offset) cell in a single batched write — quantize-on-
        # write when the pool carries scale arrays (per-token granularity)
        if ksc is not None:
            kq, kss = KQ.quantize(k, scale_dtype=ksc.dtype)
            vq, vss = KQ.quantize(v, scale_dtype=vsc.dtype)
            kp = kp.at[pages, offs].set(kq)
            vp = vp.at[pages, offs].set(vq)
            ksc = ksc.at[pages, offs].set(kss)
            vsc = vsc.at[pages, offs].set(vss)
        else:
            kp = kp.at[pages, offs].set(k.astype(kp.dtype))
            vp = vp.at[pages, offs].set(v.astype(vp.dtype))
        if s == 1:
            fn = (PA.paged_attention
                  if kernels.paged_attention_impl == "kernel"
                  else KR.paged_attention_ref)
            out = fn(q[:, 0], kp, vp, block_tables, seq_lens + 1,
                     k_scales=ksc, v_scales=vsc)[:, None]
        else:
            wl = (write_lens if write_lens is not None
                  else jnp.full((b,), s, jnp.int32))
            if kernels.paged_prefill_impl == "kernel":
                out = PA.paged_prefill(q, kp, vp, block_tables, seq_lens,
                                       seq_lens + wl, k_scales=ksc,
                                       v_scales=vsc,
                                       q_chunk=_resolve_q_chunk(
                                           kernels, chunk, s, cfg, ps))
            else:
                out = KR.paged_prefill_ref(q, kp, vp, block_tables, seq_lens,
                                           seq_lens + wl, k_scales=ksc,
                                           v_scales=vsc)
        new_cache = {"k_pages": kp, "v_pages": vp}
        if ksc is not None:
            new_cache.update(k_scales=ksc, v_scales=vsc)
    else:
        kc, vc = cache["k"], cache["v"]
        ksl, vsl = cache.get("k_scale"), cache.get("v_scale")
        cap = kc.shape[1]
        is_ring = bool(window) and cap == window + num_sink
        bidx = jnp.arange(b)[:, None]
        tpos = seq_lens[:, None] + jnp.arange(s)[None, :]          # (B, S) abs
        if is_ring:
            # attend over [old ring ; fresh block] jointly, THEN commit — a
            # write-first ring would let late block tokens overwrite early
            # tokens' window during chunked prefill.
            rw = cap - num_sink
            j = jnp.arange(cap)[None, :]
            jr = j - num_sink
            rlen_old = (seq_lens - num_sink)[:, None]
            p_ring = ((rlen_old - 1 - jr) // rw) * rw + jr + num_sink
            kpos_c = jnp.where(j < num_sink, j, p_ring)            # (B, cap)
            valid_c = jnp.where(j < num_sink, j < seq_lens[:, None],
                                (p_ring >= num_sink) & (p_ring < seq_lens[:, None]))
            k_all = jnp.concatenate([kc.astype(k.dtype), k], axis=1)
            v_all = jnp.concatenate([vc.astype(v.dtype), v], axis=1)
            kpos = jnp.concatenate([kpos_c, tpos], axis=1)
            valid = jnp.concatenate([valid_c, jnp.ones(tpos.shape, bool)], axis=1)
            out = attend(q, k_all, v_all, qpos=tpos, kpos=kpos, valid=valid,
                         causal=True, window=window, num_sink=num_sink,
                         chunk=chunk)
            slot = jnp.where(tpos < num_sink, tpos,
                             num_sink + (tpos - num_sink) % rw)
            kc = kc.at[bidx, slot].set(k.astype(kc.dtype))
            vc = vc.at[bidx, slot].set(v.astype(vc.dtype))
        else:
            # bucketed prefill: right-padded positions (>= write_lens) are
            # pointed past the cache and *dropped* — the old
            # ``minimum(tpos, cap - 1)`` clamp scattered pad garbage into
            # cell cap-1 whenever the bucket overhung the capacity.  Any
            # genuine position overrun drops the same way instead of
            # corrupting the last live cell.
            slot = tpos
            if write_lens is not None:
                slot = jnp.where(jnp.arange(s)[None, :] < write_lens[:, None],
                                 slot, cap)
            if ksl is not None:       # quantize-on-write, per-token scales
                kq, kss = KQ.quantize(k, scale_dtype=ksl.dtype)
                vq, vss = KQ.quantize(v, scale_dtype=vsl.dtype)
                kc = kc.at[bidx, slot].set(kq, mode="drop")
                vc = vc.at[bidx, slot].set(vq, mode="drop")
                ksl = ksl.at[bidx, slot].set(kss, mode="drop")
                vsl = vsl.at[bidx, slot].set(vss, mode="drop")
            else:
                kc = kc.at[bidx, slot].set(k.astype(kc.dtype), mode="drop")
                vc = vc.at[bidx, slot].set(v.astype(vc.dtype), mode="drop")
            out = attend(q, kc, vc, qpos=tpos, causal=True, window=window,
                         num_sink=num_sink, chunk=chunk, grouped=s <= 8,
                         k_scale=ksl, v_scale=vsl)
        new_cache = {"k": kc, "v": vc}
        if ksl is not None:
            new_cache.update(k_scale=ksl, v_scale=vsl)
    out = out.reshape(b, s, cfg.num_heads * hd)
    # row-parallel epilogue (DESIGN.md §17): under tensor-parallel serving
    # each device holds its head-slice of wo's K axis, so the projection is
    # a partial sum until the psum completes it; identity otherwise
    return L.tp_all_reduce(
        L.linear(p["wo"], out, name="wo", kernels=kernels)), new_cache


# ------------------------------------------------------------------------- MLA
def mla_init(rng, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": L.linear_init(ks[0], d, h * qk, dtype=dtype),
        "wkv_a": L.linear_init(ks[1], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim,
                               dtype=dtype),
        "kv_norm": L.norm_init(cfg.kv_lora_rank, "rmsnorm", dtype),
        "wkv_b": L.linear_init(ks[2], cfg.kv_lora_rank,
                               h * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype=dtype),
        "wo": L.linear_init(ks[3], h * cfg.v_head_dim, d, dtype=dtype),
    }


def _mla_expand(p, c_kv, cfg, kernels, b, n, h):
    """Expand compressed kv: (B, N, dc) -> k_nope (B,N,H,dn), v (B,N,H,dv)."""
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    kv = L.linear(p["wkv_b"], c_kv, name="wkv_b", kernels=kernels)
    kv = kv.reshape(b, n, h, dn + dv)
    return kv[..., :dn], kv[..., dn:]


def mla_apply(p, x, *, cfg: ModelConfig, kernels=L.DEFAULT_KERNELS,
              positions=None, cache=None, seq_lens=None):
    b, s, d = x.shape
    h = cfg.num_heads
    dn, dr, dv, dc = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim, cfg.kv_lora_rank)
    if positions is None:
        positions = jnp.arange(s)[None, :].astype(jnp.int32) + jnp.zeros((b, 1), jnp.int32)

    q = L.linear(p["wq"], x, name="wq", kernels=kernels).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.linear(p["wkv_a"], x, name="wkv_a", kernels=kernels)
    c_kv, k_rope = kv_a[..., :dc], kv_a[..., dc:]
    c_kv = L.apply_norm(p["kv_norm"], c_kv, norm_type="rmsnorm", eps=cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)

    scale = 1.0 / math.sqrt(dn + dr)
    if cache is None:
        # train / one-shot prefill: expanded attention over the block
        k_nope, v = _mla_expand(p, c_kv, cfg, kernels, b, s, h)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        qpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        out = attend(qq, k, v, qpos=qpos, causal=True, scale=scale,
                     chunk=cfg.attn_q_chunk)
        new_cache = None
    else:
        cc = cache["c"]
        cap = cc.shape[1]
        bidx = jnp.arange(b)[:, None]
        tpos = seq_lens[:, None] + jnp.arange(s)[None, :]
        new_c = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], -1)    # (B,S,dc+dr)
        cc = cc.at[bidx, jnp.minimum(tpos, cap - 1)].set(new_c.astype(cc.dtype))
        if s > 1:
            # prefill with cache: expand the (updated) compressed cache and run
            # chunked expanded attention (absorbed is decode-only)
            cached_c = cc[..., :dc].astype(x.dtype)
            cached_r = cc[..., dc:].astype(x.dtype)
            k_nope, v = _mla_expand(p, cached_c, cfg, kernels, b, cap, h)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(cached_r[:, :, None, :], (b, cap, h, dr))], -1)
            qq = jnp.concatenate([q_nope, q_rope], -1)
            out = attend(qq, k, v, qpos=tpos, causal=True, scale=scale,
                         chunk=cfg.attn_q_chunk)
        else:
            # decode: absorbed path — attend in compressed space (MLA's point:
            # the cache stores dc+dr per token instead of 2*H*D)
            from repro.core.gptq import QuantizedLinear, dequantize
            wb = p["wkv_b"]["w"]
            if isinstance(wb, QuantizedLinear):
                wb = dequantize(wb, x.dtype)
            wb = wb.reshape(dc, h, dn + dv)
            wb_k, wb_v = wb[..., :dn], wb[..., dn:]
            q_c = jnp.einsum("bshn,chn->bshc", q_nope, wb_k,
                             preferred_element_type=jnp.float32).astype(x.dtype)
            cached_c, cached_r = cc[..., :dc], cc[..., dc:]
            logits = (jnp.einsum("bshc,blc->bhsl", q_c, cached_c,
                                 preferred_element_type=jnp.float32)
                      + jnp.einsum("bshr,blr->bhsl", q_rope, cached_r,
                                   preferred_element_type=jnp.float32)) * scale
            kpos = jnp.arange(cap)[None, None, None, :]
            mask = kpos <= tpos[:, None, :, None]
            logits = jnp.where(mask, logits, NEG_INF)
            pr = jax.nn.softmax(logits, axis=-1)
            o_c = jnp.einsum("bhsl,blc->bshc", pr.astype(cc.dtype), cached_c,
                             preferred_element_type=jnp.float32).astype(x.dtype)
            out = jnp.einsum("bshc,chv->bshv", o_c, wb_v,
                             preferred_element_type=jnp.float32).astype(x.dtype)
        new_cache = {"c": cc}
    out = out.reshape(b, s, h * dv)
    return L.linear(p["wo"], out, name="wo", kernels=kernels), new_cache


# ----------------------------------------------------------------- cache inits
def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: int = 0, num_sink: int = 0, dtype=jnp.bfloat16,
                   kv_quant=None):
    cap = min(max_len, window + num_sink) if window else max_len
    shape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
    if kv_quant is not None and kv_quant.quantized:
        if window:
            raise ValueError(
                "quantized KV does not support sliding-window ring caches")
        if kv_quant.granularity != "token":
            raise ValueError(
                "the slot cache stores per-token scales; per-page scales "
                "exist only in the paged layout")
        sdt = kv_quant.scale_jnp_dtype
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], sdt),
                "v_scale": jnp.zeros(shape[:-1], sdt)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {"c": jnp.zeros((batch, max_len,
                            cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype)}
