import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes (16x16 single-pod / 2x16x16 multi-pod) and record
memory + roofline terms.  ShapeDtypeStruct stand-ins only — no allocation.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]

Results are cached to experiments/dryrun/<cell>.json; the EXPERIMENTS.md
tables are generated from these files (perf/report.py).
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable, get_config, input_specs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.gptq import GPTQConfig
from repro.core.quantize_model import abstract_quantized_params
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models import layers as L
from repro.perf import roofline as R
from repro.sharding import partition as SP
from repro.training import optimizer as O
from repro.training.train_loop import TrainState, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _shape_of(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               opt_state_dtype: str = "float32", remat: str | None = None,
               extra_cfg: dict | None = None):
    """Returns (fn, abstract_args, in_shardings, out_shardings, n_active)."""
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    model = build_model(cfg)
    batch_abs = input_specs(cfg, shape)
    batch_shard = SP.batch_specs(batch_abs, cfg, mesh)

    if shape.kind == "train":
        params_abs = model.abstract_params()
        p_shard = SP.param_shardings(params_abs, cfg, mesh)
        opt_cfg = O.OptimizerConfig(state_dtype=opt_state_dtype)
        opt_abs = jax.eval_shape(lambda p: O.init_opt_state(p, opt_cfg), params_abs)
        opt_shard = SP.opt_state_shardings(opt_abs, p_shard, mesh)
        rng_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
        state_abs = TrainState(params=params_abs, opt_state=opt_abs, rng=rng_abs)
        state_shard = TrainState(params=p_shard, opt_state=opt_shard,
                                 rng=SP.replicated(mesh))
        step = make_train_step(model, opt_cfg)
        repl = SP.replicated(mesh)
        metr_shard = {"loss": repl, "aux": repl, "grad_norm": repl, "lr": repl}
        # MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)
        return (step, (state_abs, batch_abs), (state_shard, batch_shard),
                (state_shard, metr_shard), cfg.active_param_count())

    # inference shapes: GPTQ-int4 weights (the paper's setting)
    params_abs = abstract_quantized_params(model.abstract_params(),
                                           GPTQConfig(group_size=128))
    p_shard = SP.param_shardings(params_abs, cfg, mesh)
    b = shape.global_batch
    repl = SP.replicated(mesh)

    logits_spec = SP.sanitize_spec(P(None, "model"), (b, cfg.vocab_size), mesh)

    if shape.kind == "prefill":
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len, dtype=jnp.bfloat16))
        c_shard = SP.cache_specs(cache_abs, cfg, mesh)
        lens_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
        lens_shard = SP.batch_specs({"x": lens_abs}, cfg, mesh)["x"]
        logits_shard = NamedSharding(mesh, logits_spec)

        def prefill_step(params, batch, cache, seq_lens):
            return model.prefill(params, batch, cache, seq_lens)

        return (prefill_step, (params_abs, batch_abs, cache_abs, lens_abs),
                (p_shard, batch_shard, c_shard, lens_shard),
                (logits_shard, c_shard, lens_shard),
                cfg.active_param_count())

    # decode: one token against a cache filled to ~seq_len
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len, dtype=jnp.bfloat16))
    c_shard = SP.cache_specs(cache_abs, cfg, mesh)
    lens_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    lens_shard = SP.batch_specs({"x": lens_abs}, cfg, mesh)["x"]
    logits_shard = NamedSharding(mesh, logits_spec)
    tokens_abs = batch_abs["tokens"]
    extra_keys = {k: v for k, v in batch_abs.items() if k != "tokens"}

    def decode(params, tokens, cache, seq_lens, extra):
        return model.decode_step(params, tokens, cache, seq_lens, extra=extra)

    extra_shard = SP.batch_specs(extra_keys, cfg, mesh)
    return (decode,
            (params_abs, tokens_abs, cache_abs, lens_abs, extra_keys),
            (p_shard, batch_shard["tokens"], c_shard, lens_shard, extra_shard),
            (logits_shard, c_shard, lens_shard),
            cfg.active_param_count())


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             opt_state_dtype: str | None = None, remat: str | None = None,
             extra_cfg: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    cell = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'singlepod'}{tag}"
    if not ok:
        return {"cell": cell, "status": "skipped", "reason": why}

    # default memory-fit policies (recorded in EXPERIMENTS.md)
    if opt_state_dtype is None:
        opt_state_dtype = "bfloat16" if cfg.param_count() > 2e11 else "float32"

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    # constrain activations: (B,S,D) carries batch-sharded AND sequence-
    # sharded over the model axis (Megatron-style sequence parallelism — the
    # remat-saved residual stack is L x B x S x D and must not replicate over
    # 'model'); attention q/k/v + logits shard over heads (padded if needed)
    r = SP.rules_for_mesh(mesh)
    bax = SP._bax_for(mesh, r, shape.global_batch)
    bspec = bax or None
    seq_spec = r.tp if (shape.kind != "decode"
                        and shape.seq_len % mesh.shape[r.tp] == 0) else None
    if shape.kind == "decode" and not cfg.is_encoder:
        # align attention compute with the KV cache layout: when kv_heads
        # doesn't divide 'model' the cache shards head_dim; constraining to
        # head sharding would reshard (all-gather) the whole cache per step.
        # With hd sharded, QK^T partial-sums all-reduce only the (tiny) logits.
        tpsz = mesh.shape[r.tp]
        if cfg.num_kv_heads and cfg.num_kv_heads % tpsz != 0 \
                and cfg.head_dim % tpsz == 0:
            heads_spec = P(bspec, None, None, r.tp)
            logits_spec = None
        else:
            heads_spec = P(bspec, None, r.tp, None)
            logits_spec = P(bspec, r.tp, None, None)
    else:
        heads_spec = P(bspec, None, r.tp, None)
        logits_spec = P(bspec, r.tp, None, None)
    # MoE (G, E, C, d) buffers: grouped dispatch shards the group dim over
    # data (scatter stays shard-local); global dispatch shards capacity
    moe_groups = (extra_cfg or {}).get("moe_dispatch_groups",
                                       cfg.moe_dispatch_groups)
    moe_spec = (P(bspec, r.tp, None, None) if moe_groups > 1
                else P(None, r.tp, bspec, None))
    L.set_act_sharding(P(bspec, seq_spec, None),
                       heads_spec=heads_spec,
                       logits_spec=logits_spec,
                       moe_spec=moe_spec)
    L.set_moe_ep(mesh, "data", r.tp, bspec)
    try:
        fn, args, in_sh, out_sh, n_active = build_cell(
            cfg, shape, mesh, opt_state_dtype=opt_state_dtype, remat=remat,
            extra_cfg=extra_cfg)
        donate = (0,) if shape.kind == "train" else (2,)   # state / cache
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    finally:
        L.set_act_sharding(None)
        L.set_moe_ep(None, "", "", None)

    mf = R.model_flops(cfg, shape, n_active)
    roof = R.analyze(compiled, n_devices=n_dev, model_flops_global=mf)
    ma = compiled.memory_analysis()

    # analytic per-device memory (exact sharded state + activation model);
    # the raw CPU memory_analysis is kept for reference but inflates bf16
    # loop state ~3x (float-normalization-bf16 — see perf/memory_model.py)
    from repro.perf import memory_model as MM
    if shape.kind == "train":
        mem_est = MM.estimate(cfg, shape, mesh, state_abs=args[0],
                              state_shardings=in_sh[0],
                              seq_sharded=True)
    else:
        mem_est = MM.estimate(cfg, shape, mesh, state_abs=args[0],
                              state_shardings=in_sh[0], cache_abs=args[2],
                              cache_shardings=in_sh[2], seq_sharded=True)

    rec = {
        "cell": cell, "status": "ok", "arch": arch, "shape": shape_name,
        "multi_pod": multi_pod, "n_devices": n_dev,
        "opt_state_dtype": opt_state_dtype if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_est.to_dict(),
        "memory_xla_cpu": {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
        },
        "roofline": roof.to_dict(),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    from repro.configs import ARCH_IDS
    cells = []
    archs = ARCH_IDS if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else ([args.shape] if args.shape else list(SHAPES))
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        name = f"{a}__{s}__{'multipod' if mp else 'singlepod'}"
        out = RESULTS_DIR / f"{name}.json"
        if out.exists() and not args.force:
            print(f"[cached] {name}")
            continue
        try:
            rec = run_cell(a, s, multi_pod=mp, remat=args.remat)
        except Exception as e:  # a failing cell is a bug — record it loudly
            rec = {"cell": name, "status": "failed", "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(rec, indent=2))
        st = rec["status"]
        n_ok += st == "ok"; n_skip += st == "skipped"; n_fail += st == "failed"
        extra = (f" mem={rec['memory']['total_gb']:.2f}GB"
                 f" fits={rec['memory']['fits_16gb']}"
                 f" dom={rec['roofline']['dominant']}" if st == "ok"
                 else rec.get("reason", rec.get("error", ""))[:100])
        print(f"[{st}] {name} {extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
