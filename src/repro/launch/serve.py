"""Serving driver: GPTQ-quantize a model and either run a synthetic request
stream through the continuous-batching engine (offline mode, default) or
expose it as an OpenAI-style HTTP service (``--serve``).

  # offline throughput run
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --requests 8 --strategy opt4gptq [--no-pallas] [--cache paged]

  # HTTP service: POST /v1/completions (token-id prompts, SSE streaming),
  # GET /metrics (Prometheus text) and GET /healthz (watchdog freshness)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --serve --port 8000

Observability (DESIGN.md §15): ``--trace-out trace.json`` attaches a
step-span ``Tracer`` and writes a Chrome/Perfetto ``trace_event`` file on
exit; ``--log-json`` switches the driver's own progress lines to one JSON
object per line (machine-parseable event log); ``--no-metrics`` swaps the
engine's registry for the zero-cost null one.
"""
import argparse
import json
import sys
import time


def log_event(args, event: str, **fields):
    """One structured driver event: human line by default, one JSON object
    per line under ``--log-json`` (``{"event": ..., **fields}``)."""
    if getattr(args, "log_json", False):
        print(json.dumps({"event": event, **fields}, sort_keys=True),
              flush=True)
    else:
        kv = " ".join(f"{k}={v}" for k, v in fields.items())
        print(f"[serve] {event}: {kv}" if kv else f"[serve] {event}",
              flush=True)


def build_engine(args):
    """Model + quantization + engine from CLI args — shared by both modes."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.core.gptq import GPTQConfig
    from repro.core.opt_strategies import get_strategy
    from repro.core.quantize_model import quantize_params
    from repro.models import build_model, layers as L
    from repro.serving.api import EngineConfig
    from repro.serving.engine import Engine
    from repro.serving.spec_decode import SpecConfig
    from repro.serving.tracing import Tracer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    kern = L.KernelConfig(strategy=get_strategy(args.strategy),
                          use_pallas=not args.no_pallas,
                          block_sizes=(8, 64, 64))
    tracer = Tracer() if args.trace_out else None
    spec = None
    if args.speculate != "off":
        spec = SpecConfig(method=args.speculate, k=args.spec_k,
                          draft_arch=args.draft_arch,
                          draft_smoke=args.smoke)
    tp = getattr(args, "tp", 1) or 1
    eng = Engine(model, qparams, EngineConfig(
        batch_slots=args.slots, max_len=args.max_len, kernels=kern,
        eos_id=-1, cache=args.cache, page_size=args.page_size,
        kv_quant=args.kv_quant, max_queued=args.max_queued,
        default_queue_timeout_s=args.queue_timeout,
        metrics=not args.no_metrics, tracer=tracer,
        speculation=spec, prefix_cache_path=args.prefix_cache,
        mesh_shape=(tp,) if tp > 1 else None))
    if tp > 1:
        log_event(args, "tensor_parallel", tp=tp,
                  devices=len(jax.devices()))
    return cfg, eng


def export_trace(args, eng):
    """Flush still-open request spans and write the Perfetto trace file."""
    if eng.tracer is None:
        return
    eng.tracer.flush_open(eng.clock.now())
    path = eng.tracer.export(args.trace_out)
    log_event(args, "trace_exported", path=path,
              events=len(eng.tracer.events))


def run_offline(args, cfg, eng):
    from repro.data.pipeline import sharegpt_stream

    stream = sharegpt_stream(args.requests, vocab_size=cfg.vocab_size,
                             seed=0, mean_prompt=10, mean_output=args.max_new,
                             max_prompt=args.max_len // 2)
    t0 = time.time()
    for r in stream:
        eng.submit(r.prompt, max_new_tokens=min(r.output_len, args.max_new))
    persist = args.prefix_cache and args.cache == "paged"
    if persist:
        # drain drops every published prefix entry (refcount reaches zero),
        # so the warm set must be captured while requests are still live —
        # pump manually and snapshot once about halfway through the stream
        done, saved = [], None
        while not eng.sched.idle:
            done.extend(eng.step())
            if saved is None and len(done) >= max(1, args.requests // 2):
                saved = eng.save_prefix_cache(args.prefix_cache)
        if saved is None:
            saved = eng.save_prefix_cache(args.prefix_cache)
    else:
        done = eng.run()
    dt = time.time() - t0
    toks = sum(len(f.output) for f in done)
    lat = sorted(f.latency for f in done)
    s = eng.stats
    if args.log_json:
        log_event(args, "offline_done", arch=cfg.name,
                  strategy=args.strategy, cache=args.cache,
                  requests=len(done), tokens=toks,
                  tok_per_s=round(toks / dt, 2),
                  p50_latency_s=round(lat[len(lat) // 2], 4),
                  wall_s=round(s.wall_s, 4), steps=s.steps,
                  tokens_per_step=round(s.tokens_per_step, 3),
                  prefix_hit_pages=s.prefix_hit_pages,
                  prefix_hit_tokens=s.prefix_hit_tokens,
                  spec_proposed=s.spec_proposed,
                  spec_accepted=s.spec_accepted,
                  acceptance_rate=round(s.acceptance_rate, 4))
    else:
        extra = ""
        if args.cache == "paged":
            extra = (f", prefix-hit pages {s.prefix_hit_pages}"
                     f" ({s.prefix_hit_tokens} tokens)")
        if args.speculate != "off":
            extra += (f", spec accept {s.spec_accepted}/{s.spec_proposed}"
                      f" ({s.acceptance_rate:.0%},"
                      f" {s.tokens_per_step:.2f} tok/step)")
        print(f"[serve] {cfg.name} x {args.strategy} [{args.cache}]: "
              f"{len(done)} reqs, {toks} tokens, {toks / dt:.2f} tok/s "
              f"(interpret), p50 {lat[len(lat) // 2]:.2f}s{extra}")
    if persist:
        log_event(args, "prefix_cache_saved", path=args.prefix_cache,
                  pages=saved)
    export_trace(args, eng)


def run_http(args, cfg, eng):
    from repro.serving.http_api import make_server

    server = make_server(eng, host=args.host, port=args.port,
                         model_name=cfg.name,
                         stall_timeout_s=args.stall_timeout)
    log_event(args, "listening", arch=cfg.name, cache=args.cache,
              url=f"http://{args.host}:{server.port}/v1/completions",
              metrics=f"http://{args.host}:{server.port}/metrics",
              healthz=f"http://{args.host}:{server.port}/healthz")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        # a second Ctrl-C during shutdown (worker join) must not lose the
        # trace — export runs no matter how shutdown ends
        try:
            server.shutdown()
        except KeyboardInterrupt:
            pass
        finally:
            export_trace(args, eng)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--strategy", default="opt4gptq")
    ap.add_argument("--no-pallas", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache", choices=("slot", "paged"), default="slot",
                    help="KV layout: fixed slots or PagedAttention block "
                         "tables (DESIGN.md §10)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (DESIGN.md §17): shard "
                         "GPTQ weights and the KV page pools across this "
                         "many devices (paged cache only; page budget is "
                         "per device)")
    ap.add_argument("--kv-quant", choices=("fp32", "bf16", "int8"),
                    default=None, dest="kv_quant",
                    help="KV-cache storage: fp passthrough or int8 with "
                         "fused per-token scales (DESIGN.md §12)")
    ap.add_argument("--speculate", choices=("off", "ngram", "draft"),
                    default="off",
                    help="speculative decoding (DESIGN.md §16): model-free "
                         "n-gram prompt lookup or a smaller draft model, "
                         "verified in one batched forward per step")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify step")
    ap.add_argument("--draft-arch", default=None, metavar="ARCH",
                    help="registry config for --speculate draft (honours "
                         "--smoke); must share the target vocab")
    ap.add_argument("--prefix-cache", default=None, metavar="DIR",
                    help="persisted prefix-cache directory: warm pages are "
                         "loaded at startup if present (paged cache only); "
                         "offline mode snapshots the live index there "
                         "mid-run (drain evicts published entries)")
    ap.add_argument("--serve", action="store_true",
                    help="run the OpenAI-style /v1/completions HTTP "
                         "front-end instead of the offline request stream")
    ap.add_argument("--max-queued", type=int, default=None,
                    help="bounded admission: reject submits past this many "
                         "queued requests with HTTP 429 (DESIGN.md §14)")
    ap.add_argument("--queue-timeout", type=float, default=None,
                    help="shed requests not admitted within this many "
                         "seconds (HTTP 503, DESIGN.md §14)")
    ap.add_argument("--stall-timeout", type=float, default=None,
                    help="arm the engine-worker watchdog: fail in-flight "
                         "requests if a step stalls past this (§14)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="HTTP port for --serve (0 = ephemeral)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-request/step spans and write a "
                         "Chrome/Perfetto trace_event JSON file on exit "
                         "(DESIGN.md §15)")
    ap.add_argument("--log-json", action="store_true",
                    help="emit driver progress as one JSON object per line "
                         "instead of human-readable text")
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable the metrics registry (NullRegistry: "
                         "/metrics exposes nothing, EngineStats reads zero)")
    args = ap.parse_args(argv)

    cfg, eng = build_engine(args)
    if args.serve:
        run_http(args, cfg, eng)
    else:
        run_offline(args, cfg, eng)
    return 0


if __name__ == "__main__":
    sys.exit(main())
