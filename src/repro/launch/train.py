"""Distributed training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b \
      --steps 100 --batch 8 --seq 128 [--mesh 2x2] [--smoke] \
      [--ckpt-dir /tmp/ck] [--fake-devices 8]

On a real TPU cluster this runs under `jax.distributed.initialize()` with the
production mesh; on CPU use --fake-devices/--mesh for small-scale runs.
"""
import argparse
import dataclasses
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs import get_config, smoke_config
    from repro.data.pipeline import LMDataPipeline
    from repro.launch.mesh import make_mesh
    from repro.models import build_model, layers as L
    from repro.runtime.fault_tolerance import resilient_train_loop
    from repro.sharding import partition as SP
    from repro.training import optimizer as O
    from repro.training.train_loop import (TrainState, init_train_state,
                                           make_train_step)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    opt = O.OptimizerConfig(learning_rate=args.lr, warmup_steps=10,
                            total_steps=args.steps)
    state = init_train_state(model, opt, jax.random.key(0))
    step_fn = make_train_step(model, opt, accum_steps=args.accum)

    dims = tuple(int(x) for x in args.mesh.split("x"))
    if len(dims) == 2 and dims[0] * dims[1] > 1:
        mesh = make_mesh(dims, ("data", "model"))
        psh = SP.param_shardings(state.params, cfg, mesh)
        osh = SP.opt_state_shardings(state.opt_state, psh, mesh)
        ssh = TrainState(params=psh, opt_state=osh, rng=SP.replicated(mesh))
        r = SP.rules_for_mesh(mesh)
        L.set_act_sharding(P(SP._bax_for(mesh, r, args.batch) or None, None, None))
        with mesh:
            step_fn = jax.jit(step_fn, in_shardings=(ssh, None))
    else:
        step_fn = jax.jit(step_fn)

    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    ck = Checkpointer(args.ckpt_dir or "/tmp/repro_train_ck", keep=2)
    to_batch = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    state, log, start = resilient_train_loop(
        step_fn, state, pipe, steps=args.steps, ckpt=ck,
        ckpt_every=args.ckpt_every, to_batch=to_batch)
    print(f"[train] {args.arch}: resumed@{start}, "
          f"loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}, "
          f"ckpts {ck.all_steps()}")


if __name__ == "__main__":
    main()
