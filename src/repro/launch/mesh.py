"""Production mesh builders (functions, never module-level constants — jax
device state must not be touched at import time)."""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / small-scale runs).  Raises a ``ValueError``
    naming the requested shape and the available device count when they
    don't match, instead of surfacing a raw jax reshape error."""
    need = math.prod(shape)
    avail = len(jax.devices())
    if need != avail:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {need} devices but "
            f"{avail} are available; on CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before jax initializes (or use make_host_mesh for a "
            f"subset-sized 1-D mesh)")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_host_mesh(n: int, axes: tuple[str, ...] = ("model",)):
    """1-D mesh over the first ``n`` local devices — the CPU-simulated
    mesh tensor-parallel serving tests run on (``n`` may be smaller than
    the device count, unlike ``make_mesh``).  Host runs need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported before
    jax initializes."""
    if n <= 0:
        raise ValueError(f"host mesh size must be >= 1, got {n}")
    if len(axes) != 1:
        raise ValueError(f"make_host_mesh builds 1-D meshes, got axes "
                         f"{tuple(axes)}")
    devices = jax.devices()
    if n > len(devices):
        raise ValueError(
            f"host mesh of {n} devices requested but only {len(devices)} "
            f"are available; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"jax initializes")
    return jax.sharding.Mesh(np.asarray(devices[:n]), tuple(axes))
