"""Production mesh builders (functions, never module-level constants — jax
device state must not be touched at import time)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / small-scale runs)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
