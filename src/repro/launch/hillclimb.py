import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb runner: compile a cell with a named variant (extra config /
remat / dispatch policy), record its roofline next to the baseline.

  PYTHONPATH=src python -m repro.launch.hillclimb <arch> <shape> <tag> \
      [--extra '{"moe_dispatch_groups": 16}'] [--remat dots] [--multi-pod]

Results land in experiments/hillclimb/<cell>__<tag>.json; EXPERIMENTS.md §Perf
is written from these.
"""
import argparse
import json
import pathlib

from repro.launch.dryrun import run_cell

OUT = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "hillclimb"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("tag")
    ap.add_argument("--extra", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt-dtype", default=None)
    args = ap.parse_args()
    extra = json.loads(args.extra) if args.extra else None
    OUT.mkdir(parents=True, exist_ok=True)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   opt_state_dtype=args.opt_dtype, remat=args.remat,
                   extra_cfg=extra, tag=f"__{args.tag}")
    rec["variant"] = {"tag": args.tag, "extra": extra, "remat": args.remat}
    out = OUT / f"{args.arch}__{args.shape}__{args.tag}.json"
    out.write_text(json.dumps(rec, indent=2))
    if rec["status"] == "ok":
        ro = rec["roofline"]
        print(f"[{args.tag}] comp={ro['compute_s']:.3f}s mem={ro['memory_s']:.3f}s "
              f"coll={ro['collective_s']:.3f}s dom={ro['dominant']} "
              f"ratio={ro['useful_ratio']:.3f} mem_gb={rec['memory']['total_gb']:.2f}")
        print("collectives:", {k: f"{v['bytes'] / 1e9:.1f}GB"
                               for k, v in ro["collectives"].items()})
    else:
        print(rec["status"], rec.get("error", "")[:300])


if __name__ == "__main__":
    main()
