"""Analytic TPU-v5e performance model for the GPTQ kernel strategies.

This is the quantitative mapping of the paper's ablation onto TPU terms
(DESIGN.md §2): each strategy changes HBM bytes moved and/or the compute unit,
and the model charges exactly those differences:

  naive     : + full bf16 W round-trip through HBM (write then re-read)
  SMB off   : + (K/bk - 1) extra fp32 read+write sweeps of the output block
              (K-outermost grid revisits the HBM output — the atomicAdd analogue)
  VML off   : weights cost 2x bytes (int8-expanded instead of packed int32)
  ILA off   : UNFUSED dequant: an extra VPU pass over the weight tile that
              cannot overlap the matmul, and the MXU runs at a 2:1 derate
              (the packed-fp16-FMA vs compiler-scalar ratio on GCN — the
              paper's v_mad_f16 effect, not a 50x unit swap)

time = max(memory, compute) per kernel invocation (perfect overlap — an upper
bound both paths share, so *relative* strategy effects are conservative).
NB: on v5e, decode is HBM-bound, so the memory opts (VML/SMB) dominate where
the paper's DCU saw ILA dominate — the bottleneck shifts with the hardware;
EXPERIMENTS.md reports both attributions.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.opt_strategies import KernelStrategy

PEAK_MXU = 197e12
PEAK_VPU = 3.9e12
HBM_BW = 819e9
BK_DEFAULT = 512
ILA_OFF_MXU_DERATE = 0.5     # packed 2-way fp16 FMA vs scalar sequence (GCN)


@dataclasses.dataclass(frozen=True)
class KernelCost:
    hbm_bytes: float
    mxu_flops: float
    vpu_flops: float

    @property
    def time_s(self) -> float:
        mem = self.hbm_bytes / HBM_BW
        comp = self.mxu_flops / PEAK_MXU + self.vpu_flops / PEAK_VPU
        return max(mem, comp)


def gptq_matmul_cost(m: int, k: int, n: int, *, group_size: int = 128,
                     strategy: KernelStrategy, bk: int = BK_DEFAULT,
                     act_bytes: int = 2) -> KernelCost:
    g = group_size if group_size > 0 else k
    w_packed = k * n // 2 + (k // g) * n * 2 + (k // g) * n // 2
    w_int8 = k * n + (k // g) * n * 2 + (k // g) * n // 2
    x_bytes = m * k * act_bytes
    out_once = m * n * act_bytes

    matmul_flops = 2.0 * m * k * n
    dequant_flops = 2.0 * k * n                  # (q - z) * s on the VPU

    if not strategy.fused:                       # naive two-pass
        w_bytes = w_packed if strategy.packed_loads else w_int8
        pass1 = w_bytes + k * n * 2              # read packed, write bf16 W
        pass2 = k * n * 2 + x_bytes + out_once   # re-read bf16 W
        return KernelCost(hbm_bytes=pass1 + pass2,
                          mxu_flops=matmul_flops,
                          vpu_flops=dequant_flops)

    w_bytes = w_packed if strategy.packed_loads else w_int8
    hbm = w_bytes + x_bytes
    if strategy.accum_vmem:
        hbm += out_once                          # single writeback
    else:
        sweeps = max(k // bk, 1)
        hbm += out_once + 2.0 * m * n * 4 * max(sweeps - 1, 0)
    if strategy.mxu:
        # fused: dequant overlaps the MXU pipeline (charged as free)
        return KernelCost(hbm, matmul_flops, 0.0)
    # unfused: serial VPU dequant pass + derated MXU
    return KernelCost(hbm, matmul_flops / ILA_OFF_MXU_DERATE, dequant_flops)


# --------------------------------------------------------------- model level
def _linear_shapes(cfg: ModelConfig) -> list[tuple[int, int]]:
    """(K, N) of every quantized matmul in one layer."""
    d, hd = cfg.d_model, cfg.head_dim
    shapes = [
        (d, cfg.num_heads * hd), (d, cfg.num_kv_heads * hd),
        (d, cfg.num_kv_heads * hd), (cfg.num_heads * hd, d),
    ]
    if cfg.act == "swiglu":
        shapes += [(d, cfg.d_ff), (d, cfg.d_ff), (cfg.d_ff, d)]
    else:
        shapes += [(d, cfg.d_ff), (cfg.d_ff, d)]
    return shapes


def decode_step_cost(cfg: ModelConfig, batch: int, context: int, *,
                     strategy: KernelStrategy, group_size: int = 128) -> float:
    """Seconds per decode step (one token for `batch` sequences)."""
    t = 0.0
    for k, n in _linear_shapes(cfg):
        t += gptq_matmul_cost(batch, k, n, group_size=group_size,
                              strategy=strategy).time_s
    t *= cfg.num_layers
    # attention: read the KV cache (strategy-independent)
    kv_bytes = (2.0 * cfg.num_layers * batch * context
                * cfg.num_kv_heads * cfg.head_dim * 2)
    t += kv_bytes / HBM_BW
    # output head (fp16, not quantized)
    head = 2.0 * cfg.d_model * cfg.vocab_size
    t += max(head / HBM_BW, 2.0 * batch * cfg.d_model * cfg.vocab_size / PEAK_MXU)
    return t


def prefill_cost(cfg: ModelConfig, batch: int, prompt: int, *,
                 strategy: KernelStrategy, group_size: int = 128) -> float:
    """Seconds to prefill `prompt` tokens for `batch` sequences."""
    m = batch * prompt
    t = 0.0
    for k, n in _linear_shapes(cfg):
        t += gptq_matmul_cost(m, k, n, group_size=group_size,
                              strategy=strategy).time_s
    t *= cfg.num_layers
    # attention flops (MXU): 2 * 2 * B * S^2 * H * hd (causal halves it)
    attn = 2.0 * batch * prompt * prompt * cfg.num_heads * cfg.head_dim
    t += attn / PEAK_MXU
    return t


def _decode_total(cfg, batch, prompt, output, strategy, group_size) -> float:
    """Total decode seconds, sampling the growing context at 8 points."""
    n_samples = min(output, 8)
    per_sample = output // n_samples
    total = 0.0
    for i in range(n_samples):
        ctx = prompt + i * per_sample
        total += per_sample * decode_step_cost(
            cfg, batch, ctx, strategy=strategy, group_size=group_size)
    return total


def request_latency(cfg: ModelConfig, *, strategy: KernelStrategy,
                    batch: int = 32, prompt: int = 256, output: int = 128,
                    group_size: int = 128) -> float:
    """End-to-end seconds for one batch of requests (paper Fig. 3 shape)."""
    return (prefill_cost(cfg, batch, prompt, strategy=strategy,
                         group_size=group_size)
            + _decode_total(cfg, batch, prompt, output, strategy, group_size))


def serving_throughput(cfg: ModelConfig, *, strategy: KernelStrategy,
                       batch: int = 32, prompt: int = 256, output: int = 128,
                       group_size: int = 128) -> float:
    """Generated tokens/s for the paper's workload shape (batch of 32
    prompts, ShareGPT-like lengths) — paper Fig. 2's metric."""
    total = request_latency(cfg, strategy=strategy, batch=batch,
                            prompt=prompt, output=output,
                            group_size=group_size)
    return batch * output / total
