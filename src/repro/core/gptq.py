"""GPTQ: accurate post-training 4-bit quantization (Frantar et al., 2022) in JAX.

Quantizes ``W`` of a linear layer ``y = x @ W`` (W: (K, N), K = in_features)
column-group-wise along K using approximate second-order information:

    H     = 2/nsamples * sum_i x_i x_i^T           (K, K)  input Hessian
    U     = chol_upper(H^{-1})                      (via damped Cholesky)
    for each input row k (in act-order if enabled):
        q_k   = clamp(round(w_k / s_g) + z_g)       group-wise asymmetric grid
        err_k = (w_k - dequant(q_k)) / U[k, k]
        W[k+1:, :] -= U[k, k+1:]^T err_k            (error feedback)

Outputs the AutoGPTQ interchange layout (see ``core/packing.py``):
``qweight (K//8, N) int32``, ``scales (K//G, N)``, ``qzeros (K//G, N//8) int32``,
plus ``perm (K,) int32`` when act-order is on (the paper kernel's ``b_q_perm``).

Note: we use the modern zero-point convention (no AutoGPTQ legacy ``z-1`` bias).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import packing


@dataclasses.dataclass(frozen=True)
class GPTQConfig:
    bits: int = 4
    group_size: int = 128          # -1 => one group for the whole K axis
    act_order: bool = False        # quantize high-curvature rows first (desc diag H)
    percdamp: float = 0.01         # Hessian damping fraction of mean diag
    sym: bool = False              # symmetric grid (zero fixed at 2^(b-1))
    scale_dtype: Any = jnp.float32

    @property
    def qmax(self) -> int:
        return (1 << self.bits) - 1


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["qweight", "scales", "qzeros", "perm", "bias"],
    meta_fields=["shape", "group_size"])
@dataclasses.dataclass
class QuantizedLinear:
    """Pytree holding one GPTQ-quantized weight matrix (+ optional bias).
    Registered as a dataclass pytree so tree paths carry field names (the
    sharding rule engine keys on them)."""
    qweight: jnp.ndarray           # (K//8, N) int32, row-packed nibbles
    scales: jnp.ndarray            # (G, N)
    qzeros: jnp.ndarray            # (G, N//8) int32, col-packed nibbles
    perm: jnp.ndarray | None       # (K,) int32 act-order permutation or None
    bias: jnp.ndarray | None
    shape: tuple[int, int]         # (K, N) logical
    group_size: int


def quant_grid(w_group: jnp.ndarray, qmax: int, sym: bool):
    """Per-column (N) asymmetric min/max grid over a (g, N) group of rows."""
    wmax = jnp.maximum(w_group.max(axis=0), 0.0)
    wmin = jnp.minimum(w_group.min(axis=0), 0.0)
    if sym:
        amax = jnp.maximum(wmax, -wmin)
        scale = jnp.where(amax > 0, 2.0 * amax / qmax, 1.0)
        zero = jnp.full_like(scale, (qmax + 1) // 2)
    else:
        rng = wmax - wmin
        scale = jnp.where(rng > 0, rng / qmax, 1.0)
        zero = jnp.clip(jnp.round(-wmin / scale), 0, qmax)
    return scale, zero


def quantize_rtn(w: jnp.ndarray, cfg: GPTQConfig):
    """Round-to-nearest baseline (no error feedback) — the paper's implicit
    'just quantize' comparison point and our property-test oracle."""
    k, n = w.shape
    g = cfg.group_size if cfg.group_size > 0 else k
    assert k % g == 0
    wg = w.reshape(k // g, g, n)
    scales, zeros, qs = [], [], []
    for i in range(k // g):
        s, z = quant_grid(wg[i], cfg.qmax, cfg.sym)
        q = jnp.clip(jnp.round(wg[i] / s[None, :]) + z[None, :], 0, cfg.qmax)
        scales.append(s); zeros.append(z); qs.append(q)
    q = jnp.concatenate(qs, axis=0).astype(jnp.int8)
    return q, jnp.stack(scales), jnp.stack(zeros).astype(jnp.int8)


def accumulate_hessian(h: jnp.ndarray | None, x: jnp.ndarray) -> jnp.ndarray:
    """Running (unnormalized) Hessian accumulation 2 * X^T X over calib batches.

    x: (..., K) activations feeding the linear; flattened over leading dims.
    """
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    update = 2.0 * (xf.T @ xf)
    return update if h is None else h + update


def _inv_hessian_chol(h: jnp.ndarray, percdamp: float) -> jnp.ndarray:
    """U = chol_upper(H^{-1}) with damping and dead-column handling."""
    k = h.shape[0]
    diag = jnp.diagonal(h)
    dead = diag == 0.0
    h = h + jnp.diag(jnp.where(dead, 1.0, 0.0))
    damp = percdamp * jnp.mean(jnp.where(dead, 0.0, diag))
    h = h + damp * jnp.eye(k, dtype=h.dtype)
    hinv = jnp.linalg.inv(h)  # (sym PD after damping)
    # upper Cholesky: chol(hinv) lower -> transpose
    u = jnp.linalg.cholesky(hinv).T
    return u


@functools.partial(jax.jit, static_argnames=("group_size", "qmax", "sym"))
def _gptq_core(w: jnp.ndarray, u: jnp.ndarray, *, group_size: int, qmax: int,
               sym: bool):
    """Sequential row-wise GPTQ with error feedback. w: (K, N) fp32 (already
    permuted if act-order). Returns (q (K,N) int8, scales (G,N), zeros (G,N))."""
    k, n = w.shape
    g = group_size
    ngroups = k // g

    def group_body(gi, carry):
        w, q, scales, zeros = carry
        w_grp = jax.lax.dynamic_slice_in_dim(w, gi * g, g, axis=0)
        s, z = quant_grid(w_grp, qmax, sym)
        scales = jax.lax.dynamic_update_slice_in_dim(scales, s[None], gi, axis=0)
        zeros = jax.lax.dynamic_update_slice_in_dim(zeros, z[None], gi, axis=0)

        def row_body(j, carry2):
            w, q = carry2
            i = gi * g + j
            wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=0)[0]      # (N,)
            d = u[i, i]
            qi = jnp.clip(jnp.round(wi / s) + z, 0, qmax)
            dq = (qi - z) * s
            err = (wi - dq) / d
            # error feedback to rows > i (U is upper triangular: U[i, :i] = 0,
            # and the i-th row itself is already quantized -> mask <= i)
            urow = jnp.where(jnp.arange(k) > i, u[i, :], 0.0)
            w = w - urow[:, None] * err[None, :]
            q = jax.lax.dynamic_update_slice_in_dim(
                q, qi[None].astype(jnp.int8), i, axis=0)
            return w, q

        w, q = jax.lax.fori_loop(0, g, row_body, (w, q))
        return w, q, scales, zeros

    q0 = jnp.zeros((k, n), jnp.int8)
    s0 = jnp.zeros((ngroups, n), jnp.float32)
    z0 = jnp.zeros((ngroups, n), jnp.float32)
    _, q, scales, zeros = jax.lax.fori_loop(
        0, ngroups, group_body, (w, q0, s0, z0))
    return q, scales, zeros.astype(jnp.int8)


def gptq_quantize(w: jnp.ndarray, hessian: jnp.ndarray | None,
                  cfg: GPTQConfig = GPTQConfig(),
                  bias: jnp.ndarray | None = None) -> QuantizedLinear:
    """Quantize one (K, N) weight matrix. ``hessian=None`` -> identity (RTN+EF)."""
    k, n = w.shape
    g = cfg.group_size if cfg.group_size > 0 else k
    assert k % g == 0, f"K={k} not divisible by group_size={g}"
    assert k % 8 == 0 and n % 8 == 0, f"K,N must be multiples of 8, got {w.shape}"
    w = w.astype(jnp.float32)
    h = jnp.eye(k, dtype=jnp.float32) if hessian is None else hessian.astype(jnp.float32)

    perm = None
    if cfg.act_order:
        perm = jnp.argsort(-jnp.diagonal(h)).astype(jnp.int32)
        w = w[perm, :]
        h = h[perm][:, perm]

    u = _inv_hessian_chol(h, cfg.percdamp)
    q, scales, zeros = _gptq_core(w, u, group_size=g, qmax=cfg.qmax, sym=cfg.sym)

    return QuantizedLinear(
        qweight=packing.pack_int4_rows(q),
        scales=scales.astype(cfg.scale_dtype),
        qzeros=packing.pack_int4_cols(zeros),
        perm=perm,
        bias=bias,
        shape=(k, n),
        group_size=g,
    )


def dequantize(ql: QuantizedLinear, dtype=jnp.float32) -> jnp.ndarray:
    """Reference full dequantization back to (K, N) in *original* row order."""
    k, n = ql.shape
    q = packing.unpack_int4_rows(ql.qweight, k).astype(jnp.float32)       # (K, N)
    z = packing.unpack_int4_cols(ql.qzeros, n).astype(jnp.float32)        # (G, N)
    s = ql.scales.astype(jnp.float32)                                     # (G, N)
    g = ql.group_size
    w = (q.reshape(k // g, g, n) - z[:, None, :]) * s[:, None, :]
    w = w.reshape(k, n)
    if ql.perm is not None:
        inv = jnp.argsort(ql.perm)
        w = w[inv, :]
    return w.astype(dtype)


def quantization_error(w: jnp.ndarray, ql: QuantizedLinear,
                       hessian: jnp.ndarray | None = None) -> jnp.ndarray:
    """Proxy loss: tr((W-Wq)^T H (W-Wq)) / tr(W^T H W) (H=I if None)."""
    dw = (w.astype(jnp.float32) - dequantize(ql))
    if hessian is None:
        return jnp.sum(dw * dw) / jnp.maximum(jnp.sum(w.astype(jnp.float32) ** 2), 1e-9)
    num = jnp.einsum("kn,kj,jn->", dw, hessian, dw)
    den = jnp.einsum("kn,kj,jn->", w, hessian, w)
    return num / jnp.maximum(den, 1e-9)
