"""The paper's three optimizations as composable kernel strategy flags.

Mapping (see DESIGN.md §2):

* ``fused``       — False = vLLM-naive two-pass (dequant W4->bf16 to HBM, then a
                    second matmul pass re-reads it).  All paper variants are fused.
* ``accum_vmem``  — SMB-Opt analogue. True: fp32 VMEM scratch accumulator,
                    K-innermost grid, single HBM writeback (`@pl.when(k==last)`).
                    False: K-OUTERMOST grid so every K step revisits the output
                    block through HBM (read-modify-write), the analogue of
                    per-thread atomicAdd traffic on the DCU.
* ``packed_loads``— VML-Opt analogue. True: weights loaded as packed int32 words
                    (8 nibbles / word). False: pre-expanded int8 weights (2x HBM
                    bytes, narrow loads).
* ``mxu``         — ILA-Opt analogue. True: dequantized tile fed to the MXU
                    (`jnp.dot`, f32 accum). False: VPU multiply+add loop over K
                    (the compiler-scalar-code analogue).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KernelStrategy:
    name: str
    fused: bool = True
    accum_vmem: bool = False
    packed_loads: bool = False
    mxu: bool = False


# The paper's ablation grid (Figs. 2-3). "baseline" is vLLM's existing fused
# exllama-style kernel with none of the three opts; "naive" is the strawman
# unfused path (worse than the paper's baseline, included for the roofline).
NAIVE = KernelStrategy("naive", fused=False, accum_vmem=False, packed_loads=False, mxu=True)
BASELINE = KernelStrategy("baseline")
SMB = KernelStrategy("smb", accum_vmem=True)
VML = KernelStrategy("vml", packed_loads=True)
ILA = KernelStrategy("ila", mxu=True)
OPT4GPTQ = KernelStrategy("opt4gptq", accum_vmem=True, packed_loads=True, mxu=True)

STRATEGIES = {s.name: s for s in [NAIVE, BASELINE, SMB, VML, ILA, OPT4GPTQ]}


def get_strategy(name: str) -> KernelStrategy:
    try:
        return STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown kernel strategy {name!r}; "
                       f"available: {sorted(STRATEGIES)}") from None
