"""int4 <-> int32 packing for GPTQ weights.

Two layouts:

* ``row_packed`` — the AutoGPTQ/exllama interchange format the paper's kernel
  consumes: ``qweight[K // 8, N] : int32`` where word ``qweight[i, n]`` holds
  nibbles for rows ``8*i .. 8*i+7`` of column ``n`` (row ``8*i`` in the least
  significant nibble).  ``qzeros[K // group, N // 8] : int32`` packs zero points
  along N.

* ``lane_packed`` — the TPU-friendly layout used by the Pallas kernel's
  VML-analogue: same row-major nibble order but kept as ``int32`` words along K
  so a single (8,128) VMEM tile load brings 8x the weight rows.  It is the same
  array as ``row_packed`` — the distinction is purely which axis the BlockSpec
  tiles — so no repack cost is paid at load time.  The *unpacked* ``int8``
  format (2x HBM bytes) exists only as the VML-off baseline.

All functions are pure jnp and jittable; numpy twins are provided for
checkpoint-side packing.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NIBBLES_PER_WORD = 8  # 8 x int4 per int32


def pack_int4_rows(w_int4: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 values (stored in an int8/int32 array, values in [0, 15]) along
    axis 0 (the K axis) into int32 words: (K, N) -> (K//8, N)."""
    k, n = w_int4.shape
    assert k % NIBBLES_PER_WORD == 0, f"K={k} not divisible by 8"
    w = w_int4.astype(jnp.uint32).reshape(k // NIBBLES_PER_WORD, NIBBLES_PER_WORD, n)
    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))[None, :, None]
    packed = jnp.sum(w << shifts, axis=1, dtype=jnp.uint32)
    return packed.astype(jnp.int32)


def unpack_int4_rows(qweight: jnp.ndarray, k: int | None = None) -> jnp.ndarray:
    """Unpack int32 words along axis 0 into int4 values: (K//8, N) -> (K, N) int8."""
    kw, n = qweight.shape
    q = qweight.astype(jnp.uint32)
    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))[None, :, None]
    nib = (q[:, None, :] >> shifts) & jnp.uint32(0xF)
    out = nib.reshape(kw * NIBBLES_PER_WORD, n).astype(jnp.int8)
    if k is not None:
        out = out[:k]
    return out


def pack_int4_cols(z_int4: jnp.ndarray) -> jnp.ndarray:
    """Pack along axis 1 (N axis), AutoGPTQ qzeros layout: (G, N) -> (G, N//8)."""
    g, n = z_int4.shape
    assert n % NIBBLES_PER_WORD == 0, f"N={n} not divisible by 8"
    z = z_int4.astype(jnp.uint32).reshape(g, n // NIBBLES_PER_WORD, NIBBLES_PER_WORD)
    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))[None, None, :]
    return jnp.sum(z << shifts, axis=2, dtype=jnp.uint32).astype(jnp.int32)


def unpack_int4_cols(qzeros: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
    """(G, N//8) int32 -> (G, N) int8."""
    g, nw = qzeros.shape
    q = qzeros.astype(jnp.uint32)
    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))[None, None, :]
    nib = (q[:, :, None] >> shifts) & jnp.uint32(0xF)
    out = nib.reshape(g, nw * NIBBLES_PER_WORD).astype(jnp.int8)
    if n is not None:
        out = out[:, :n]
    return out


# ---------------------------------------------------------------- numpy twins
def np_pack_int4_rows(w_int4: np.ndarray) -> np.ndarray:
    k, n = w_int4.shape
    assert k % NIBBLES_PER_WORD == 0
    w = w_int4.astype(np.uint32).reshape(k // NIBBLES_PER_WORD, NIBBLES_PER_WORD, n)
    shifts = (4 * np.arange(NIBBLES_PER_WORD, dtype=np.uint32))[None, :, None]
    return np.sum(w << shifts, axis=1, dtype=np.uint32).astype(np.int32)


def np_unpack_int4_rows(qweight: np.ndarray, k: int | None = None) -> np.ndarray:
    kw, n = qweight.shape
    q = qweight.astype(np.uint32)
    shifts = (4 * np.arange(NIBBLES_PER_WORD, dtype=np.uint32))[None, :, None]
    nib = (q[:, None, :] >> shifts) & np.uint32(0xF)
    out = nib.reshape(kw * NIBBLES_PER_WORD, n).astype(np.int8)
    return out if k is None else out[:k]
