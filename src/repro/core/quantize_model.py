"""Whole-model GPTQ quantization: walk a parameter tree and replace every
eligible projection weight with a ``QuantizedLinear`` (concrete arrays, via
the GPTQ algorithm + captured Hessians) or with abstract ShapeDtypeStructs
(for the dry-run's serving memory/roofline analysis).

Eligible = transformer projection matrices (attention, FFN, SSM, per-expert
tensors). Embeddings, output head, norms, routers, conv and SSM scan tensors
stay fp (matching AutoGPTQ / the paper's vLLM setup).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.gptq import GPTQConfig, QuantizedLinear, gptq_quantize

PROJ_PARENTS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj", "out_proj",
    "x_proj", "dt_proj", "wkv_a", "wkv_b", "head_proj",
}
EXPERT_NAMES = {"w_gate", "w_up", "w_down"}


def _path_parts(path):
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
        else:
            out.append(str(e))
    return out


def _eligible(parts: list[str], leaf) -> str | None:
    """Returns 'proj' | 'expert' | None. Operates on logical (trailing) dims."""
    last = parts[-1]
    if last == "w" and len(parts) >= 2 and parts[-2] in PROJ_PARENTS:
        return "proj"
    if last in EXPERT_NAMES and "experts" in parts:
        return "expert"
    return None


def _quant_group(k: int, group_size: int) -> int | None:
    """Largest usable group size for a K dim (None -> not quantizable)."""
    if k % 8 != 0:
        return None
    if group_size > 0 and k % group_size == 0:
        return group_size
    return k                                  # single whole-K group


def abstract_quantized_params(abstract_params, cfg_gptq: GPTQConfig,
                              scale_dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree with QuantizedLinear stand-ins (dry-run serving)."""

    def f(path, leaf):
        parts = _path_parts(path)
        kind = _eligible(parts, leaf)
        if kind is None:
            return leaf
        *lead, k, n = leaf.shape
        g = _quant_group(k, cfg_gptq.group_size)
        if g is None or n % 8 != 0:
            return leaf
        ngroups = k // g
        sds = jax.ShapeDtypeStruct
        return QuantizedLinear(
            qweight=sds((*lead, k // 8, n), jnp.int32),
            scales=sds((*lead, ngroups, n), scale_dtype),
            qzeros=sds((*lead, ngroups, n // 8), jnp.int32),
            perm=None, bias=None, shape=(k, n), group_size=g)

    return jax.tree_util.tree_map_with_path(f, abstract_params)


def quantize_params(params, hessians: dict[str, Any] | None,
                    cfg_gptq: GPTQConfig, scale_dtype=jnp.bfloat16):
    """Concrete whole-model quantization. ``hessians`` maps qualified names
    ("layer3.wq" style, from layers.capture_hessians) to (K, K) arrays; missing
    entries quantize with H=I (RTN + error feedback).

    Stacked leading dims (scan groups L, experts E) are quantized slice-wise
    and restacked."""
    hessians = hessians or {}

    def lookup_h(parts, idx):
        # capture names are "layer{i}.{proj}" within a group; fall back to None
        for key in (".".join(parts), f"layer{idx}.{parts[-2] if len(parts) > 1 else parts[-1]}"):
            if key in hessians:
                return hessians[key]
        return None

    def quant_one(w, h):
        return gptq_quantize(
            w, h, dataclasses.replace(cfg_gptq, scale_dtype=scale_dtype))

    def f(path, leaf):
        parts = _path_parts(path)
        kind = _eligible(parts, leaf)
        if kind is None:
            return leaf
        *lead, k, n = leaf.shape
        g = _quant_group(k, cfg_gptq.group_size)
        if g is None or n % 8 != 0:
            return leaf
        cfg_local = dataclasses.replace(cfg_gptq, group_size=g)
        if not lead:
            return gptq_quantize(leaf, lookup_h(parts, 0), cfg_local)
        flat = leaf.reshape(-1, k, n)
        quants = [gptq_quantize(flat[i], lookup_h(parts, i), cfg_local)
                  for i in range(flat.shape[0])]
        stack = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs).reshape(
            *lead, *xs[0].shape), *quants)
        return stack

    return jax.tree_util.tree_map_with_path(f, params)


def dequantize_tree(params, dtype=jnp.bfloat16):
    """Inverse walk (testing): QuantizedLinear leaves -> dense arrays."""
    from repro.core.gptq import dequantize

    def is_ql(x):
        return isinstance(x, QuantizedLinear)

    def f(leaf):
        if not is_ql(leaf):
            return leaf
        if leaf.qweight.ndim == 2:
            return dequantize(leaf, dtype)
        *lead, kw, n = leaf.qweight.shape
        k = leaf.shape[0]
        flat_q = leaf.qweight.reshape(-1, kw, n)
        flat_s = leaf.scales.reshape(-1, leaf.scales.shape[-2], n)
        flat_z = leaf.qzeros.reshape(-1, leaf.qzeros.shape[-2], leaf.qzeros.shape[-1])
        outs = [dequantize(QuantizedLinear(flat_q[i], flat_s[i], flat_z[i],
                                           None, None, leaf.shape,
                                           leaf.group_size), dtype)
                for i in range(flat_q.shape[0])]
        return jnp.stack(outs).reshape(*lead, k, n)

    return jax.tree_util.tree_map(f, params, is_leaf=is_ql)
