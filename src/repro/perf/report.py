"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run result cache. Usage:

  PYTHONPATH=src python -m repro.perf.report > experiments/tables.md
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load(mesh: str | None = None):
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and not r["cell"].endswith(mesh):
            continue
        recs.append(r)
    return recs


def fmt_bytes(gb):
    return f"{gb:.2f}"


def dryrun_table() -> str:
    out = ["| cell | status | mesh | state GB/dev | cache GB/dev | resid GB/dev | work GB/dev | total GB/dev | fits 16GB | compile s |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load():
        if r["status"] == "skipped":
            out.append(f"| {r['cell']} | SKIP: {r['reason'][:60]} | | | | | | | | |")
            continue
        if r["status"] == "failed":
            out.append(f"| {r['cell']} | **FAILED** | | | | | | | | |")
            continue
        m = r["memory"]
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        out.append(
            f"| {r['cell']} | ok | {mesh} | {m['state_gb']:.2f} | {m['cache_gb']:.2f} "
            f"| {m['residual_gb']:.2f} | {m['working_gb']:.2f} | **{m['total_gb']:.2f}** "
            f"| {'yes' if m['fits_16gb'] else 'NO'} | {r['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table(mesh="singlepod") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops | wire GB/dev | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        hint = _hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
            f"| {ro['collective_s']:.3f} | **{ro['dominant']}** | {ro['useful_ratio']:.3f} "
            f"| {ro['wire_bytes_per_dev'] / 1e9:.1f} | {hint} |")
    return "\n".join(out)


def _hint(r) -> str:
    ro = r["roofline"]
    dom = ro["dominant"]
    colls = ro.get("collectives", {})
    big = max(colls, key=lambda k: colls[k]["bytes"]) if colls else "none"
    if dom == "collective":
        return f"cut {big} volume (sharding/layout: see §Perf)"
    if dom == "memory":
        if r["shape"].startswith("decode"):
            return "KV cache reads dominate; quantize/shard cache further"
        return "fuse elementwise chains; raise arithmetic intensity (remat policy)"
    return "already compute-bound; raise MFU via larger per-chip tiles"


def main():
    print("## §Dry-run (generated)\n")
    print(dryrun_table())
    print("\n## §Roofline — single-pod 16x16 (generated)\n")
    print(roofline_table("singlepod"))
    print("\n## §Roofline — multi-pod 2x16x16 (generated)\n")
    print(roofline_table("multipod"))


if __name__ == "__main__":
    main()
