"""While-aware cost model over post-SPMD HLO text.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, which under-reports every scanned-layer model by ~num_layers x
(verified empirically — see EXPERIMENTS.md §Roofline methodology). This module
re-derives the three roofline inputs from ``compiled.as_text()``:

  * FLOPs           — every ``dot`` op (2 * batch * m * n * k from shapes +
                      contracting dims), times its computation's execution
                      count. Elementwise flops are excluded (<5% for LMs).
  * HBM bytes       — post-fusion op boundaries approximate HBM round trips:
                      each top-level op charges operand + result bytes, with
                      in-place ops (dynamic-update-slice / scatter / aliased
                      fusions) charged only their touched region.
  * collective wire — ring-cost factors per op kind (see roofline.py).

Execution counts come from the call graph: ENTRY=1, while bodies multiply by
``known_trip_count``, fusions/to_apply inherit their caller's count.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{$")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE2 = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_ALIAS_RE = re.compile(r"output_to_operand_aliasing=\{[^=]*\}")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "while",
    "conditional", "after-all", "iota", "partition-id", "replica-id",
    "get-dimension-size", "domain", "opt-barrier", "call",
}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "ragged-all-to-all", "collective-permute", "all-reduce-start",
               "all-gather-start", "collective-permute-start"}


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    result: str
    opcode: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op] = dataclasses.field(default_factory=list)
    symbols: dict = dataclasses.field(default_factory=dict)


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        m = _HEADER_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_HEAD_RE.match(line)
        if not om:
            continue
        name = om.group(1)
        rest = line[om.end():]
        # result type: balanced-paren tuple "(...)" (may contain /*index=N*/
        # comments) or a plain "dtype[dims]{layout}" token
        if rest.startswith("("):
            depth, i = 0, 0
            while i < len(rest):
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                    if depth == 0:
                        i += 1
                        break
                i += 1
            result = rest[:i]
            rest = rest[i:].lstrip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            result = rest[:sp]
            rest = rest[sp + 1:]
        opm = re.match(r"([\w\-]+)\(", rest)
        if not opm:
            continue
        opcode = opm.group(1)
        # operands: %-tokens inside the first balanced paren group after opcode
        rest2 = rest[opm.end():]
        depth, i = 1, 0
        while i < len(rest2) and depth:
            if rest2[i] == "(":
                depth += 1
            elif rest2[i] == ")":
                depth -= 1
            i += 1
        operand_str = rest2[:i - 1] if i else ""
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        op = Op(name, result, opcode, operands, line)
        cur.ops.append(op)
        cur.symbols[name] = result
    return comps, entry


def execution_counts(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    counts: dict[str, float] = defaultdict(float)
    counts[entry] = 1.0
    # edges: (caller -> callee, multiplier)
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "while":
                trip = 1.0
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = float(tm.group(1))
                bm = _BODY_RE.search(op.line)
                cm = _COND_RE.search(op.line)
                if bm:
                    edges[c.name].append((bm.group(1), trip))
                if cm:
                    edges[c.name].append((cm.group(1), trip + 1))
            else:
                for rx in (_CALLS_RE, _TO_APPLY_RE):
                    mm = rx.search(op.line)
                    if mm:
                        edges[c.name].append((mm.group(1), 1.0))
    # propagate through the (acyclic) call graph to a fixed point
    for _ in range(100):
        new_counts: dict[str, float] = defaultdict(float)
        new_counts[entry] = 1.0
        for caller, outs in edges.items():
            base = counts.get(caller, 0.0)
            if base == 0:
                continue
            for callee, mult in outs:
                new_counts[callee] += base * mult
        new_counts[entry] = 1.0
        if dict(new_counts) == dict(counts):
            break
        counts = new_counts
    return counts


def _dot_flops(op: Op, symbols: dict) -> float:
    lhs = symbols.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 0.0
    dims_info = _shape_dims(lhs)
    if not dims_info:
        return 0.0
    lhs_dims = dims_info[0][1]
    res_info = _shape_dims(op.result)
    res_elems = 1
    for _, dims in res_info:
        for d in dims:
            res_elems *= d
    contract = 1
    cm = _LHS_C_RE.search(op.line)
    if cm:
        for idx in cm.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    return 2.0 * res_elems * contract


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE2.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return default


def _wire_bytes(op: Op, n_devices: int) -> float:
    size = shape_bytes(op.result)
    kind = op.opcode.replace("-start", "")
    g = _group_size(op.line, n_devices)
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2 * size * (g - 1) / g
    if kind == "all-gather":
        return size * (g - 1) / g
    if kind == "reduce-scatter":
        return size * (g - 1)
    if kind in ("all-to-all", "ragged-all-to-all"):
        return size * (g - 1) / g
    if kind == "collective-permute":
        return float(size)
    return 0.0


SLICE_OPS = {"dynamic-slice", "gather"}
INPLACE_OPS = {"dynamic-update-slice", "scatter"}


def _fusion_inplace_root(op: Op, comps: dict) -> int | None:
    """If the fused computation's ROOT is (a convert/bitcast chain over) a
    dynamic-update-slice whose target traces back to a parameter, return that
    parameter's index: the fusion is an in-place update and its traffic is
    the update region, not the full buffer. (XLA:CPU's bf16 float
    normalization wraps cache DUS ops in whole-buffer f32 converts — a
    backend artifact TPU does not have; see EXPERIMENTS.md methodology.)"""
    m = _CALLS_RE.search(op.line)
    if not m or m.group(1) not in comps:
        return None
    inner = comps[m.group(1)]
    param_of: dict[str, int] = {}
    by_name: dict[str, Op] = {}
    root = None
    for iop in inner.ops:
        by_name[iop.name] = iop
        if iop.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", iop.line)
            if pm:
                param_of[iop.name] = int(pm.group(1))
        if "ROOT" in iop.line:
            root = iop
    if root is None and inner.ops:
        root = inner.ops[-1]

    def walk(name_or_op, depth=0):
        o = name_or_op if isinstance(name_or_op, Op) else by_name.get(name_or_op)
        while o is not None and depth < 8 and o.opcode in ("convert", "bitcast",
                                                           "copy", "reshape"):
            o = by_name.get(o.operands[0]) if o.operands else None
            depth += 1
        return o

    dus = walk(root)
    if dus is None or dus.opcode != "dynamic-update-slice" or not dus.operands:
        return None
    target = walk(dus.operands[0])
    if target is not None and target.name in param_of:
        return param_of[target.name]
    return None


def _fusion_sliced_params(op: Op, comps: dict) -> set[int]:
    """Parameter indices of a fusion that are consumed ONLY by slice/gather
    ops inside the fused computation (HBM reads the slice, not the operand)."""
    m = _CALLS_RE.search(op.line)
    if not m or m.group(1) not in comps:
        return set()
    inner = comps[m.group(1)]
    param_of: dict[str, int] = {}
    for iop in inner.ops:
        if iop.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", iop.line)
            if pm:
                param_of[iop.name] = int(pm.group(1))
    sliced: dict[int, bool] = {}
    for iop in inner.ops:
        for o in iop.operands:
            if o in param_of:
                idx = param_of[o]
                is_slice = (iop.opcode in SLICE_OPS
                            or (iop.opcode in INPLACE_OPS and iop.operands
                                and iop.operands[0] == o))
                sliced[idx] = sliced.get(idx, True) and is_slice
    return {i for i, ok in sliced.items() if ok}


def _hbm_bytes(op: Op, symbols: dict, comps: dict | None = None) -> float:
    oc = op.opcode
    if oc in SKIP_BYTES_OPS or oc.endswith("-done"):
        return 0.0
    if oc in INPLACE_OPS:
        # in-place: charge read+write of the update region + indices
        upd = symbols.get(op.operands[1], "") if len(op.operands) > 1 else ""
        idx = sum(shape_bytes(symbols.get(o, "")) for o in op.operands[2:])
        return 2.0 * shape_bytes(upd) + idx
    if oc in SLICE_OPS:
        idx = sum(shape_bytes(symbols.get(o, "")) for o in op.operands[1:])
        return 2.0 * shape_bytes(op.result) + idx
    result_b = float(shape_bytes(op.result))
    total = result_b
    operands = list(op.operands)
    sizes = [shape_bytes(symbols.get(o, "")) for o in operands]
    if _ALIAS_RE.search(op.line):
        # in-place (DUS-style) fusion: the aliased buffer is neither fully
        # read nor fully written — traffic ~= read update + write region
        if sizes:
            sizes.remove(max(sizes))
        return 2.0 * sum(sizes)
    if oc == "fusion" and comps is not None:
        ip = _fusion_inplace_root(op, comps)
        if ip is not None and ip < len(sizes):
            # in-place DUS fusion: read+write the update region only
            rest = [s for j, s in enumerate(sizes) if j != ip]
            return 2.0 * sum(rest)
        sliced = _fusion_sliced_params(op, comps)
        for i in sliced:
            if i < len(sizes):
                # operand only sliced inside: charge the slice (~result size)
                sizes[i] = min(sizes[i], int(result_b))
    return total + sum(sizes)


@dataclasses.dataclass
class HloCost:
    flops: float                 # per device, dots only, while-corrected
    hbm_bytes: float             # per device, post-fusion op boundaries
    wire_bytes: float            # per device, ring-cost collectives
    collectives: dict
    n_while: int
    max_trip: float

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze_text(text: str, n_devices: int) -> HloCost:
    comps, entry = parse_module(text)
    if entry is None:
        return HloCost(0, 0, 0, {}, 0, 0)
    counts = execution_counts(comps, entry)

    # computations reachable ONLY as fusion/apply bodies: flops yes, bytes no.
    byte_comps = {entry}
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "while":
                bm = _BODY_RE.search(op.line)
                cm = _COND_RE.search(op.line)
                if bm:
                    byte_comps.add(bm.group(1))
                if cm:
                    byte_comps.add(cm.group(1))
            elif op.opcode == "call":
                mm = _TO_APPLY_RE.search(op.line)
                if mm:
                    byte_comps.add(mm.group(1))

    flops = 0.0
    hbm = 0.0
    wire = 0.0
    coll: dict = {}
    n_while = 0
    max_trip = 0.0
    for c in comps.values():
        n = counts.get(c.name, 0.0)
        if n == 0:
            continue
        for op in c.ops:
            if op.opcode == "while":
                n_while += 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    max_trip = max(max_trip, float(tm.group(1)))
            if op.opcode in ("dot", "convolution"):
                flops += n * _dot_flops(op, c.symbols)
            if op.opcode in COLLECTIVES:
                w = n * _wire_bytes(op, n_devices)
                wire += w
                k = coll.setdefault(op.opcode.replace("-start", ""),
                                    {"bytes": 0.0, "count": 0})
                k["bytes"] += w
                k["count"] += int(n)
            if c.name in byte_comps:
                hbm += n * _hbm_bytes(op, c.symbols, comps)
    return HloCost(flops=flops, hbm_bytes=hbm, wire_bytes=wire,
                   collectives=coll, n_while=n_while, max_trip=max_trip)
