"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / link_bw

``cost_analysis()`` on a post-SPMD executable reports the PER-DEVICE program
(verified empirically: matmul flops / n_devices), so the per-chip form above
equals the prompt's global form HLO/(chips x peak).

Collective bytes are parsed from ``compiled.as_text()`` (post-SPMD HLO), using
standard ring-algorithm wire-cost factors per op kind.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e hardware constants (DESIGN.md)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (1 effective link assumed)
HBM_PER_CHIP = 16e9          # bytes
VPU_FLOPS = 3.9e12           # f32 vector unit (ILA-off perf model)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE2 = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE2.search(line)
    if m:
        first = m.group(1).split("}")[0].split(",")
        return max(len([x for x in first if x.strip() != ""]), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                      # per device
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device wire bytes using ring-algorithm cost factors:
      all-reduce S      -> 2*S*(g-1)/g
      all-gather S_full -> S_full*(g-1)/g
      reduce-scatter S_in (result is the scattered shard; wire cost uses the
                       full input = result * g) -> result*(g-1)
      all-to-all S      -> S*(g-1)/g
      collective-permute S -> S
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "start" in line.split(kind)[1][:8]:   # avoid double-count of -done
            pass
        size = _shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if kind == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:                                    # collective-permute
            wire = size
        st.wire_bytes += wire
        k = st.by_kind.setdefault(kind, {"bytes": 0.0, "count": 0})
        k["bytes"] += wire
        k["count"] += 1
        st.count += 1
    return st


# HLO text lists both `op-start` and `op-done`; only count `-start` (or the
# bare op). We deduplicate by skipping lines whose op name ends in `-done`.
def _strip_done(hlo_text: str) -> str:
    return "\n".join(l for l in hlo_text.splitlines()
                     if "-done" not in l.split("=")[0])


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float          # while-corrected (perf.hlo_cost)
    bytes_per_dev: float          # while-corrected HBM estimate
    wire_bytes_per_dev: float     # while-corrected collective wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float           # MODEL_FLOPS / (flops_per_dev * chips)
    mem_per_dev_bytes: float
    fits: bool
    collectives: dict
    xla_flops_raw: float          # cost_analysis() as reported (body-once)
    xla_bytes_raw: float
    n_while: int
    max_trip: float

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, n_devices: int, model_flops_global: float,
            peak=PEAK_FLOPS, hbm=HBM_BW, link=LINK_BW) -> Roofline:
    from repro.perf import hlo_cost as H
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):        # older jax: one dict per program
        ca = ca[0] if ca else {}
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    cost = H.analyze_text(compiled.as_text(), n_devices)

    compute_s = cost.flops / peak
    memory_s = cost.hbm_bytes / hbm
    collective_s = cost.wire_bytes / link
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    ma = compiled.memory_analysis()
    mem = (ma.argument_size_in_bytes + ma.output_size_in_bytes
           + ma.temp_size_in_bytes - ma.alias_size_in_bytes)

    hlo_global = cost.flops * n_devices
    ratio = model_flops_global / hlo_global if hlo_global else 0.0
    return Roofline(
        flops_per_dev=cost.flops, bytes_per_dev=cost.hbm_bytes,
        wire_bytes_per_dev=cost.wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops_global,
        useful_ratio=ratio, mem_per_dev_bytes=float(mem),
        fits=mem < HBM_PER_CHIP, collectives=cost.collectives,
        xla_flops_raw=xla_flops, xla_bytes_raw=xla_bytes,
        n_while=cost.n_while, max_trip=cost.max_trip)


def model_flops(cfg, shape, n_active_params: int) -> float:
    """6*N*D for training, 2*N*D for inference (attention flops excluded —
    the useful_ratio is a utilization sanity metric, not an exact identity)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active_params * tokens
