"""Analytic per-device HBM accounting for the dry-run "fits" verdict.

The CPU backend's ``memory_analysis()`` is reported alongside but inflates
bf16 loop state ~3x: XLA CPU's float-normalization-bf16 pass rewrites bf16
compute to f32 (no native CPU bf16) and keeps both copies of the remat
residual stack live (verified pass-by-pass; see EXPERIMENTS.md §Dry-run
methodology). TPU executes bf16 natively, so the CPU number is a backend
artifact, not the deployment footprint.

Static state (params / optimizer / gradients / KV caches) is computed EXACTLY
from each leaf's PartitionSpec (ceil-division per sharded dim — padding
included). Activations use a structural peak model of the compiled program:
remat residual stack + one layer's live working set + chunked loss block.

Serving-side KV accounting (ISSUE 4 satellite): ``slot_cache_bytes`` /
``paged_cache_bytes`` give the exact footprint of either cache layout at any
dtype × quant mode (scale pools included), and ``kv_cache_report`` tabulates
the whole layout × dtype × quant grid — the numbers behind the int8-KV
capacity claim (2x vs bf16, 4x vs fp32 tokens per byte).
``paged_prefill_peak_bytes`` (ISSUE 5) quantifies the transient the chunked
paged-prefill kernel removes: the gather path's contiguous per-layer KV copy
(plus its dense dequant when int8) vs the kernel's zero materialization.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.serving import kv_quant as KQ


def _leaf_device_bytes(leaf, sharding, mesh) -> int:
    spec = getattr(sharding, "spec", None)
    dims = list(leaf.shape)
    if spec is not None:
        for i, ax in enumerate(spec):
            if ax is None or i >= len(dims):
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            dims[i] = math.ceil(dims[i] / n)
    n = 1
    for d in dims:
        n *= d
    return n * jnp.dtype(leaf.dtype).itemsize


def sharded_state_bytes(abstract_tree, shardings, mesh) -> int:
    leaves = jax.tree_util.tree_leaves(abstract_tree)
    shards = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: hasattr(s, "spec"))
    assert len(leaves) == len(shards), (len(leaves), len(shards))
    return sum(_leaf_device_bytes(l, s, mesh) for l, s in zip(leaves, shards))


@dataclasses.dataclass
class MemoryEstimate:
    state_gb: float          # params (+opt/grads for train), exact from specs
    cache_gb: float          # KV/SSM cache (serving), exact from specs
    residual_gb: float       # remat-saved residual stack
    working_gb: float        # peak per-layer live set + loss block
    total_gb: float
    fits_16gb: bool

    def to_dict(self):
        return dataclasses.asdict(self)


def activation_terms(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     *, seq_sharded: bool) -> tuple[float, float]:
    """(residual_bytes, working_bytes) per device."""
    from repro.sharding import partition as SP
    r = SP.rules_for_mesh(mesh)
    bax = SP._bax_for(mesh, r, shape.global_batch)
    dp = 1
    for a in bax:
        dp *= mesh.shape[a]
    tp = mesh.shape[r.tp]

    train = shape.kind == "train"
    s = shape.seq_len if shape.kind != "decode" else 1
    s_tot = s + (cfg.meta_tokens if shape.kind != "decode" else 0)
    b_loc = math.ceil(shape.global_batch / dp)
    act = 2  # bf16

    # remat residual stack: L x B_loc x S x D (seq-sharded when enabled)
    resid = 0.0
    if train:
        seq_div = tp if (seq_sharded and s_tot % tp == 0) else 1
        resid = cfg.num_layers * b_loc * (s_tot // seq_div) * cfg.d_model * act

    # one live layer working set (remat recompute peak)
    h_loc = math.ceil(max(cfg.num_heads, 1) / tp)
    qk_chunk = min(s_tot, cfg.attn_q_chunk)
    attn_logits = b_loc * h_loc * qk_chunk * s_tot * 4 * (3 if train else 2)
    qkv = b_loc * s_tot * (3 * math.ceil(
        max(cfg.num_heads, 1) * max(cfg.head_dim, 1) / tp)) * act
    if cfg.family == "ssm" or cfg.family == "hybrid":
        di_loc = math.ceil(cfg.d_inner / tp)
        ssm_ws = b_loc * s_tot * di_loc * (4 + cfg.ssm_state * 0) * 4 \
            + b_loc * di_loc * cfg.ssm_state * 4 * 2
    else:
        ssm_ws = 0
    d_ff = cfg.moe_d_ff if cfg.num_experts else cfg.d_ff
    if cfg.num_experts:
        t_glob = shape.global_batch * s
        cap_tokens = cfg.capacity_factor * cfg.num_experts_per_tok * t_glob
        ffn_ws = cap_tokens * (cfg.d_model + 2 * d_ff) * act / (tp * dp)
        ffn_ws += (cfg.num_shared_experts * 2
                   * b_loc * s_tot * math.ceil(
                       cfg.moe_d_ff * cfg.num_shared_experts / tp) * act
                   if cfg.num_shared_experts else 0)
    else:
        ffn_ws = b_loc * s_tot * math.ceil(d_ff / tp) * act * (3 if cfg.act == "swiglu" else 2)
    layer_ws = attn_logits + qkv + ssm_ws + ffn_ws

    # chunked loss block (train): B_loc x chunk x V/tp fp32, ~2 copies
    loss_ws = 0.0
    if train:
        chunk = min(1024, s)
        loss_ws = b_loc * chunk * math.ceil(cfg.vocab_size / tp) * 4 * 2
    # decode/prefill logits head block
    if not train:
        loss_ws = b_loc * (1 if shape.kind == "decode" else 1) \
            * math.ceil(cfg.vocab_size / tp) * 4 * 2
    return float(resid), float(layer_ws + loss_ws)


# -------------------------------------------------- serving KV-cache footprint
def slot_cache_bytes(cfg: ModelConfig, batch_slots: int, max_len: int, *,
                     dtype=jnp.float32, kv_quant=None) -> int:
    """Exact slot-layout KV bytes (payload + per-token scale arrays)."""
    return KQ.slot_bytes(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                         batch_slots, max_len + cfg.meta_tokens,
                         dtype=dtype, kv_quant=kv_quant)


def paged_cache_bytes(cfg: ModelConfig, num_pages: int, page_size: int, *,
                      dtype=jnp.float32, kv_quant=None) -> int:
    """Exact paged-layout KV bytes — ``num_pages`` allocatable pages plus the
    null page, scale pools included."""
    return KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                         page_size, dtype=dtype,
                         kv_quant=kv_quant) * (num_pages + 1)


def paged_cache_device_bytes(cfg: ModelConfig, num_pages: int,
                             page_size: int, *, dtype=jnp.float32,
                             kv_quant=None, tp: int = 1) -> int:
    """Per-device paged-KV bytes under ``tp``-way tensor parallelism
    (DESIGN.md §17): every device holds the ``num_kv_heads/tp`` head-slice
    of the same global page ids, so one device's pool is ``1/tp`` of the
    single-device footprint at the same page count — equivalently, the same
    per-device byte budget buys ``tp×`` the pages.  ``kv_quant`` accepts a
    ``KVQuantConfig`` or the CLI string form (``"bf16"``/``"int8"``)."""
    if isinstance(kv_quant, str):
        kv_quant = KQ.KVQuantConfig(dtype=kv_quant)
    if cfg.num_kv_heads % tp:
        raise ValueError(
            f"num_kv_heads={cfg.num_kv_heads} does not divide tp={tp}")
    return KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads // tp,
                         cfg.head_dim, page_size, dtype=dtype,
                         kv_quant=kv_quant) * (num_pages + 1)


def host_offload_bytes(cfg: ModelConfig, n_pages: int, page_size: int, *,
                       dtype=jnp.float32, kv_quant=None) -> int:
    """Host bytes one preempted sequence's checkpoint holds: its private
    pages (payload + scale pools), DESIGN.md §14.  Shared prefix pages are
    released on device, never copied, so they cost nothing here — pass the
    private page count."""
    return KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                         page_size, dtype=dtype, kv_quant=kv_quant) * n_pages


def paged_prefill_peak_bytes(cfg: ModelConfig, *, batch: int, max_pages: int,
                             page_size: int, dtype=jnp.float32, kv_quant=None,
                             impl: str = "gather") -> int:
    """Extra HBM one paged prefill attention call materializes *beyond the
    page pool itself* (ISSUE 5).

    The gather path (``paged_prefill_impl="ref"`` — the pre-kernel prefill)
    builds a contiguous (B, max_pages·page_size, Hkv, D) copy of both K and
    V per layer call; when the pool is int8 it additionally densely
    dequantizes that copy to fp32, so peak prefill bytes are the int8
    gather *plus* the fp32 copy.  The fused kernel streams one page at a
    time through VMEM and materializes nothing in HBM — 0 extra bytes,
    which is the whole point of the chunked paged-prefill kernel.
    """
    if impl == "kernel":
        return 0
    if impl != "gather":
        raise ValueError(f"impl must be 'gather' or 'kernel', got {impl!r}")
    elems = batch * max_pages * page_size * cfg.num_kv_heads * cfg.head_dim
    if kv_quant is not None and getattr(kv_quant, "quantized", False):
        per_pool = elems * (1 + 4)       # int8 gather + dense fp32 dequant
    else:
        per_pool = elems * jnp.dtype(dtype).itemsize
    return 2 * per_pool                  # K and V


def kv_cache_report(cfg: ModelConfig, *, batch_slots: int, max_len: int,
                    page_size: int, num_pages: int | None = None) -> list[dict]:
    """KV-cache bytes per layout × dtype × quant mode.

    One row per configuration: layout, mode (dtype [+ scale granularity]),
    total bytes, bytes per cache token, and the capacity factor vs the same
    layout at fp32 — how many times more tokens the same byte budget holds.
    """
    if num_pages is None:
        num_pages = KQ.default_num_pages(batch_slots, max_len, page_size)
    modes = [
        ("fp32", None),
        ("bf16", None),
        ("int8/token", KQ.KVQuantConfig(dtype="int8", granularity="token")),
        ("int8/page", KQ.KVQuantConfig(dtype="int8", granularity="page")),
    ]
    dtypes = {"fp32": jnp.float32, "bf16": jnp.bfloat16}
    rows: list[dict] = []
    for layout in ("slot", "paged"):
        if layout == "slot":
            tokens = batch_slots * (max_len + cfg.meta_tokens)
        else:
            tokens = (num_pages + 1) * page_size
        base = None
        for mode, kvq in modes:
            if layout == "slot" and mode == "int8/page":
                continue        # the slot cache stores per-token scales only
            dt = dtypes.get(mode.split("/")[0], jnp.float32)
            if layout == "slot":
                nbytes = slot_cache_bytes(cfg, batch_slots, max_len,
                                          dtype=dt, kv_quant=kvq)
            else:
                nbytes = paged_cache_bytes(cfg, num_pages, page_size,
                                           dtype=dt, kv_quant=kvq)
            base = base if base is not None else nbytes
            rows.append({
                "layout": layout, "mode": mode, "bytes": nbytes,
                "bytes_per_token": nbytes / tokens,
                "capacity_x_vs_fp32": base / nbytes,
            })
    return rows


def estimate(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
             state_abs, state_shardings, cache_abs=None, cache_shardings=None,
             seq_sharded: bool = True, hbm_gb: float = 16.0) -> MemoryEstimate:
    state = sharded_state_bytes(state_abs, state_shardings, mesh)
    cache = (sharded_state_bytes(cache_abs, cache_shardings, mesh)
             if cache_abs is not None else 0)
    resid, work = activation_terms(cfg, shape, mesh, seq_sharded=seq_sharded)
    total = state + cache + resid + work
    return MemoryEstimate(
        state_gb=state / 1e9, cache_gb=cache / 1e9, residual_gb=resid / 1e9,
        working_gb=work / 1e9, total_gb=total / 1e9,
        fits_16gb=total < hbm_gb * 1e9)
