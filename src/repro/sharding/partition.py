"""PartitionSpec rule engine: parameter-tree paths -> NamedShardings.

Scheme (DESIGN.md §5): 2D weight sharding — tensor-parallel over ``model``
(Megatron col->row within a block) x FSDP over ``data`` (the other weight
dim), activations batch-sharded over (``pod``, ``data``).  ``pod`` is pure DP:
weights/optimizer replicate across pods, gradients all-reduce over it.

MoE experts shard over ``model`` (EP) when num_experts divides the axis, else
fall back to TP inside experts (grok: 8 experts on a 16-way axis would pad).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# parameter roles by name ------------------------------------------------------
COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "wkv_b"}
ROW_PARALLEL = {"wo", "w_down", "out_proj"}
SMALL_OUT = {"wkv_a", "router"}          # (d, small): shard input dim only
SSM_IN_SMALL = {"x_proj"}                # (d_inner, small)
SSM_OUT_WIDE = {"dt_proj"}               # (small, d_inner)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Axis names used for each role (tuple entries compose)."""
    dp: tuple[str, ...] = ("data",)      # batch / FSDP axis
    tp: str = "model"                    # tensor axis
    pod: str | None = None               # pure-DP pod axis (multi-pod)

    @property
    def batch_axes(self):
        return (self.pod, *self.dp) if self.pod else self.dp


def rules_for_mesh(mesh: Mesh) -> MeshRules:
    names = mesh.axis_names
    if "pod" in names:
        return MeshRules(dp=("data",), tp="model", pod="pod")
    return MeshRules(dp=("data",), tp="model")


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _base_weight_spec(parent: str, cfg: ModelConfig, r: MeshRules,
                      model_size: int):
    """Spec for the logical (unstacked, fp) 2D weight of a named projection."""
    dp, tp = r.dp[0], r.tp
    if parent in COL_PARALLEL:
        return (dp, tp)
    if parent in ROW_PARALLEL:
        return (tp, dp)
    if parent in SMALL_OUT:
        return (dp, None)
    if parent in SSM_IN_SMALL:
        return (tp, None)
    if parent in SSM_OUT_WIDE:
        return (None, tp)
    if parent == "head":
        return (dp, tp)
    return (None, None)


def _expert_spec(name: str, cfg: ModelConfig, r: MeshRules, model_size: int):
    """(E, d, f) expert tensors: EP over model when divisible, else TP."""
    dp, tp = r.dp[0], r.tp
    ep = cfg.num_experts % model_size == 0
    if name in ("w_gate", "w_up"):
        return (tp, dp, None) if ep else (None, dp, tp)
    return (tp, None, dp) if ep else (None, tp, dp)      # w_down (E, f, d)


def param_spec(path, leaf, cfg: ModelConfig, r: MeshRules,
               model_size: int) -> P:
    ps = _path_str(path)
    parts = ps.split("/")
    last = parts[-1]
    stacked = parts[0].startswith("group")
    pre = (None,) if stacked else ()

    shape = leaf.shape
    # 0/1-D leaves: replicate (norm scales, biases, D, conv_b, perms...)
    def done(spec):
        spec = pre + tuple(spec)
        spec = spec[:len(shape)] if len(spec) > len(shape) else spec
        spec = spec + (None,) * (len(shape) - len(spec))
        return P(*spec)

    # embedding / head ---------------------------------------------------------
    if last == "embedding":
        return P(r.tp, r.dp[0])
    if len(parts) >= 2 and parts[-2] == "head" and last == "w":
        return P(r.dp[0], r.tp)
    if last == "meta":
        return P()

    # experts ------------------------------------------------------------------
    if "experts" in parts:
        return done(_expert_spec(last, cfg, r, model_size))

    # ssm direct tensors -------------------------------------------------------
    if last == "conv_w":
        return done((None, r.tp))
    if last in ("conv_b", "D"):
        return done((r.tp,))
    if last == "A_log":
        return done((r.tp, None))

    # projections: path like .../<proj>/w or QuantizedLinear attrs under w ----
    qattr = None
    if last in ("qweight", "scales", "qzeros", "perm", "bias"):
        qattr = last
        parent = parts[-3] if len(parts) >= 3 else ""
    elif last in ("w", "b"):
        parent = parts[-2] if len(parts) >= 2 else ""
    else:
        return done(())

    base = _base_weight_spec(parent, cfg, r, model_size)
    if qattr is None:
        if last == "w":
            return done(base)
        # bias: shard like the output dim
        return done((base[1],))
    # Quantized (serving) weights: TP-only — int4 fits without FSDP, and an
    # FSDP'd qweight would be all-gathered AFTER dequantization (4x the wire
    # bytes) every step (§Perf cell B iteration 5).
    dp = r.dp[0]
    qbase = tuple(None if a == dp else a for a in base)
    if qattr == "qweight":
        return done(qbase)                # K//8 rows shard like K
    if qattr == "scales":
        return done((None, qbase[1]))
    if qattr == "qzeros":
        return done((None, qbase[1]))
    if qattr == "perm":
        return done((None,))
    return done((qbase[1],))              # quantized bias


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axes whose size doesn't divide the dim — pjit input shardings
    (unlike with_sharding_constraint) reject uneven partitions (e.g. hymba's
    vocab 32001, hubert's 504)."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(ax if shape[i] % n == 0 else None)
    return P(*out)


def param_shardings(abstract_params, cfg: ModelConfig, mesh: Mesh):
    """NamedSharding tree matching an abstract (or concrete) param tree."""
    r = rules_for_mesh(mesh)
    msize = mesh.shape[r.tp]

    def f(path, leaf):
        spec = sanitize_spec(param_spec(path, leaf, cfg, r, msize),
                             leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, abstract_params)


# ----------------------------------------------------------------- activations
def _bax_for(mesh: Mesh, r: MeshRules, batch: int):
    """Batch axes, dropped when the batch doesn't divide them (long_500k B=1)."""
    bax = tuple(a for a in r.batch_axes if a)
    n = 1
    for a in bax:
        n *= mesh.shape[a]
    return bax if batch % n == 0 else ()


def batch_specs(batch_tree, cfg: ModelConfig, mesh: Mesh):
    """Shardings for a model input batch dict (tokens/labels/embeds/etc)."""
    r = rules_for_mesh(mesh)

    def f(path, leaf):
        ps = _path_str(path)
        if "positions" in ps:            # (3, B, S)
            bax = _bax_for(mesh, r, leaf.shape[1])
            return NamedSharding(mesh, P(None, bax or None, None))
        bax = _bax_for(mesh, r, leaf.shape[0])
        spec = (bax or None,) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, mesh: Mesh):
    """KV / SSM cache shardings: batch over (pod,data); model axis goes to
    kv-heads when divisible, else head_dim, else replicated.  The MLA
    compressed cache shards its (kv_lora+rope) feature dim over model (it has
    no head dim; 32k x 128-batch caches would not fit replicated)."""
    r = rules_for_mesh(mesh)
    msize = mesh.shape[r.tp]

    def shard_or_none(dim: int):
        return r.tp if dim % msize == 0 else None

    dp0 = r.dp[0]
    dpsz = mesh.shape[dp0]

    def f(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        bax = _bax_for(mesh, r, leaf.shape[1]) or None  # leading dim is L
        if ps.endswith("/c"):                       # MLA (L, B, S, dc+dr)
            seq = dp0 if (bax is None and leaf.shape[2] % dpsz == 0) else None
            return NamedSharding(mesh, P(None, bax, seq,
                                         shard_or_none(leaf.shape[-1])))
        if ps.endswith("/conv"):                    # (L, B, K-1, di)
            return NamedSharding(mesh, P(None, bax, None,
                                         shard_or_none(leaf.shape[-1])))
        if ps.endswith("/ssm"):                     # (L, B, di, S)
            return NamedSharding(mesh, P(None, bax,
                                         shard_or_none(leaf.shape[-2]), None))
        if ps.endswith("/k") or ps.endswith("/v"):  # (L, B, S, Hkv, hd)
            hkv, hd = leaf.shape[-2], leaf.shape[-1]
            # context parallelism: a batch too small for the data axis
            # (long_500k B=1) shards the cache SEQUENCE over it instead —
            # distributed attention with softmax-combine via tiny all-reduces
            seq = dp0 if (bax is None and leaf.shape[2] % dpsz == 0) else None
            if hkv % msize == 0:
                return NamedSharding(mesh, P(None, bax, seq, r.tp, None))
            return NamedSharding(mesh, P(None, bax, seq, None,
                                         shard_or_none(hd)))
        spec = (None, bax) + (None,) * (nd - 2)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, cache_tree)


def opt_state_shardings(opt_state_tree, params_shardings, mesh: Mesh):
    """m/v inherit parameter shardings (ZeRO); step replicates."""
    def f(ps_leaf):
        return ps_leaf

    return {
        "m": jax.tree_util.tree_map(f, params_shardings),
        "v": jax.tree_util.tree_map(f, params_shardings),
        "step": NamedSharding(mesh, P()),
    }


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
