"""Sharded, async, atomic checkpointing.

Layout:  <dir>/step_<N>/
           manifest.msgpack     — tree structure, shapes, dtypes, QuantizedLinear
                                  metadata, step, save wall-time
           arr_<i>.npy          — one file per leaf (per-host shards on real
                                  multi-host; full arrays in this container)
         <dir>/step_<N>.COMMIT  — atomic commit marker (rename-after-write)

Fault-tolerance contract: a checkpoint without its COMMIT marker is ignored at
restore (torn writes from a killed process can never be resumed into).
Async: `save(..., blocking=False)` snapshots to host RAM synchronously and
writes in a background thread — the train loop stalls only for the device->host
copy (straggler mitigation at scale).
"""
from __future__ import annotations

import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.gptq import QuantizedLinear


def _is_ql(x):
    return isinstance(x, QuantizedLinear)


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_ql)


def _path_str(path) -> str:
    out = []
    for e in path:
        out.append(str(getattr(e, "key", getattr(e, "name", getattr(e, "idx", e)))))
    return "/".join(out)


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------------- internal
    def _write(self, step_dir: pathlib.Path, leaves, meta):
        tmp = step_dir.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, arr in enumerate(leaves):
            np.save(tmp / f"arr_{i}.npy", arr, allow_pickle=False)
        (tmp / "manifest.msgpack").write_bytes(msgpack.packb(meta))
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp.rename(step_dir)
        commit = step_dir.parent / (step_dir.name + ".COMMIT")
        commit.write_text(str(time.time()))
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
            (self.dir / f"step_{s}.COMMIT").unlink(missing_ok=True)

    # ------------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: dict | None = None):
        """Snapshot to host, then write (optionally in the background)."""
        self.wait()
        paths_leaves, treedef = _flatten(tree)
        records, arrays = [], []
        for path, leaf in paths_leaves:
            if _is_ql(leaf):
                sub = {"qweight": leaf.qweight, "scales": leaf.scales,
                       "qzeros": leaf.qzeros, "perm": leaf.perm,
                       "bias": leaf.bias}
                present = {k: v is not None for k, v in sub.items()}
                records.append({"path": _path_str(path), "kind": "quantized",
                                "present": present,
                                "shape": list(leaf.shape),
                                "group_size": leaf.group_size})
                for k, v in sub.items():
                    if v is not None:
                        arrays.append(np.asarray(v))
            else:
                records.append({"path": _path_str(path), "kind": "array"})
                arrays.append(np.asarray(leaf))
        meta = {"step": step, "records": records, "extra": extra or {},
                "saved_at": time.time()}
        step_dir = self.dir / f"step_{step}"
        if blocking:
            self._write(step_dir, arrays, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step_dir, arrays, meta), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for c in self.dir.glob("step_*.COMMIT"):
            name = c.name[:-len(".COMMIT")]
            if (self.dir / name).exists():
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the *structure* of ``template`` (elastic: arrays are
        re-sharded onto ``shardings`` if given — mesh shape may differ from
        the one that saved). Returns (tree, extra)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        step_dir = self.dir / f"step_{step}"
        meta = msgpack.unpackb((step_dir / "manifest.msgpack").read_bytes())
        paths_leaves, treedef = _flatten(template)
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "spec"))
            if shardings is not None else [None] * len(paths_leaves))

        by_path = {}
        i = 0
        for rec in meta["records"]:
            if rec["kind"] == "quantized":
                sub = {}
                for k in ("qweight", "scales", "qzeros", "perm", "bias"):
                    if rec["present"][k]:
                        sub[k] = np.load(step_dir / f"arr_{i}.npy")
                        i += 1
                    else:
                        sub[k] = None
                by_path[rec["path"]] = ("quantized", sub, rec)
            else:
                by_path[rec["path"]] = ("array", np.load(step_dir / f"arr_{i}.npy"), None)
                i += 1

        out = []
        qi = 0
        for (path, leaf), shard in zip(paths_leaves, shard_leaves):
            key = _path_str(path)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            kind, data, rec = by_path[key]
            if kind == "quantized":
                put = (lambda a: jax.device_put(a, shard)
                       if shard is not None else jnp.asarray(a))
                out.append(QuantizedLinear(
                    qweight=jnp.asarray(data["qweight"]),
                    scales=jnp.asarray(data["scales"]),
                    qzeros=jnp.asarray(data["qzeros"]),
                    perm=None if data["perm"] is None else jnp.asarray(data["perm"]),
                    bias=None if data["bias"] is None else jnp.asarray(data["bias"]),
                    shape=tuple(rec["shape"]), group_size=rec["group_size"]))
            else:
                arr = data
                if shard is not None:
                    out.append(jax.device_put(arr, shard))
                else:
                    out.append(jnp.asarray(arr))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, meta.get("extra", {})
