"""Injectable wall-clock for the serving stack (DESIGN.md §14).

Every deadline decision in serving — queue-timeout shedding, stall
detection, Retry-After estimates — reads time through a ``Clock`` object
instead of calling ``time.time()`` directly, so fault-injection tests can
drive the clock deterministically (``ManualClock``) without real sleeps.
``tests/test_lint.py`` gates the serving modules off direct ``time.time``
calls; this module is the single permitted call site.

Timestamps recorded for *metrics* (arrival, ttft, tpot) come from the same
clock, so a test that advances a ``ManualClock`` sees consistent latencies.
"""
from __future__ import annotations

import time


class SystemClock:
    """Real wall-clock. The one place serving code touches ``time.time``."""

    def now(self) -> float:
        return time.time()


class ManualClock:
    """Deterministic test clock: advances only when told to.

    The fault-injection harness (``serving/faults.py``) uses this to
    simulate step-time stalls — advance past a watchdog timeout without
    sleeping — and queue-deadline expiry.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot rewind the clock (dt={dt})")
        self._now += dt
        return self._now


SYSTEM_CLOCK = SystemClock()
