"""Stdlib-only OpenAI-style HTTP front-end over ``Engine.stream()``.

``POST /v1/completions`` with an OpenAI-ish JSON body serves completions
from the continuous-batching engine; ``"stream": true`` switches to SSE
(``data: {chunk}\\n\\n`` per token, terminated by ``data: [DONE]``).  The
repo has no tokenizer, so ``"prompt"`` must be a list of token ids and
``choices[].text`` carries the space-joined ids alongside
``choices[].token_ids``.

Threading model: HTTP handlers run on ``ThreadingHTTPServer`` threads, but
the ``Engine`` is single-threaded — one ``EngineWorker`` thread owns it and
pumps ``step_events()``.  Handlers talk to the worker through queues only:
submissions (and aborts, on client disconnect) go through ``worker.inbox``;
each request's ``StreamEvent``s come back on a per-request queue.  Requests
submitted while others are decoding join the running batch — continuous
batching straight through HTTP.

Overload behaviour (DESIGN.md §14): bounded admission maps
``QueueFullError`` to **429** with a ``Retry-After`` header; a request shed
on its queue deadline gets **503** (+ ``Retry-After``); and when
``stall_timeout_s`` is set, a watchdog thread monitors the worker's
heartbeat and fails every in-flight request with ``FinishReason.STALL``
(**503** on the blocking path, a terminal SSE chunk on the streaming path)
instead of letting clients hang on a wedged engine.

    eng = Engine(model, params, EngineConfig(...))
    server = make_server(eng, port=8000, model_name=cfg.name)
    server.serve_forever()          # or launch/serve.py --serve
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.runtime.fault_tolerance import Heartbeat
from repro.serving.api import FinishReason, QueueFullError, StreamEvent
from repro.serving.sampler import SamplingParams

# how long a handler waits for the next token before giving up on the worker
EVENT_TIMEOUT_S = 300.0


@dataclasses.dataclass
class _Submission:
    """One HTTP request's hand-off to the engine worker."""
    tokens: list[int]
    max_new_tokens: int
    sampling: SamplingParams
    stop_token_ids: tuple[int, ...]
    ignore_eos: bool
    priority: int = 0
    queue_timeout_s: float | None = None
    # per-request StreamEvent fan-out queue, and the rid/Exception handshake
    events: queue.Queue = dataclasses.field(default_factory=queue.Queue)
    reply: queue.Queue = dataclasses.field(default_factory=queue.Queue)


class EngineWorker(threading.Thread):
    """The single thread that owns the engine.

    Drains control ops (submit/abort) from ``inbox``, pumps
    ``Engine.step_events()`` while requests are in flight, and fans each
    event out to its request's subscriber queue.  Idle polling is a short
    blocking ``inbox.get`` — no busy loop.

    With ``stall_timeout_s`` set, the loop beats a ``Heartbeat`` (read
    through the engine's injectable clock) each iteration and a watchdog
    thread ``check()``s it from outside.  On a stall the watchdog cannot
    touch the wedged engine — it fails the *clients*: every subscriber
    queue gets a synthetic terminal ``StreamEvent`` with
    ``FinishReason.STALL`` (``output is None``) and is unsubscribed, so no
    stream ever hangs past the timeout.
    """

    def __init__(self, engine, idle_poll_s: float = 0.02,
                 stall_timeout_s: float | None = None):
        super().__init__(daemon=True, name="engine-worker")
        self.eng = engine
        self.idle_poll_s = idle_poll_s
        self.inbox: "queue.Queue[tuple[str, object]]" = queue.Queue()
        self._halt = threading.Event()
        self._subs: dict[int, queue.Queue] = {}
        self._subs_lock = threading.Lock()
        self.stalled_requests = 0
        self.heartbeat: Heartbeat | None = None
        self._watchdog: threading.Thread | None = None
        if stall_timeout_s is not None:
            self.heartbeat = Heartbeat(timeout_s=stall_timeout_s,
                                       clock=engine.clock.now)
            self._watchdog = threading.Thread(
                target=self._watch, daemon=True, name="engine-watchdog")

    def start(self):
        super().start()
        if self._watchdog is not None:
            self._watchdog.start()

    # ---------------------------------------------- handler-thread interface
    def submit(self, sub: _Submission) -> int:
        """Hand a submission to the engine thread; returns its rid or raises
        the engine's validation error."""
        self.inbox.put(("submit", sub))
        res = sub.reply.get(timeout=EVENT_TIMEOUT_S)
        if isinstance(res, Exception):
            raise res
        return res

    def abort(self, rid: int):
        self.inbox.put(("abort", rid))

    def shutdown(self, timeout: float = 5.0):
        self._halt.set()
        self.join(timeout=timeout)

    # ------------------------------------------------------- engine thread
    def _handle(self, op: str, payload):
        if op == "submit":
            sub = payload
            try:
                rid = self.eng.submit(
                    sub.tokens, max_new_tokens=sub.max_new_tokens,
                    sampling=sub.sampling,
                    stop_token_ids=sub.stop_token_ids,
                    ignore_eos=sub.ignore_eos,
                    priority=sub.priority,
                    queue_timeout_s=sub.queue_timeout_s)
            except Exception as e:    # validation -> 400, QueueFull -> 429
                sub.reply.put(e)
                return
            with self._subs_lock:
                self._subs[rid] = sub.events
            sub.reply.put(rid)
        elif op == "abort":
            self.eng.abort(payload)          # terminal event reaches the
            # subscriber via the engine's event list on the next drain; a
            # disconnected client's queue simply goes unread after that
        else:                                # pragma: no cover
            raise AssertionError(f"unknown op {op!r}")

    def _fan_out(self, events):
        for ev in events:
            with self._subs_lock:
                q = self._subs.get(ev.rid)
                if q is not None and ev.finish_reason is not None:
                    self._subs.pop(ev.rid, None)
            if q is not None:
                q.put(ev)

    def _fail_subs(self, reason: FinishReason):
        """Watchdog path: terminate every subscribed client with a synthetic
        terminal event (``output is None`` — the engine never produced a
        ``RequestOutput``) and drop the subscriptions."""
        with self._subs_lock:
            victims = list(self._subs.items())
            self._subs.clear()
        for rid, q in victims:
            self.stalled_requests += 1
            q.put(StreamEvent(rid=rid, token=None, index=0,
                              finish_reason=reason, output=None))

    def _watch(self):
        hb = self.heartbeat
        poll_s = min(0.05, hb.timeout_s / 4)
        while not self._halt.is_set():
            if not hb.check():
                self._fail_subs(FinishReason.STALL)
            self._halt.wait(poll_s)

    def run(self):
        while not self._halt.is_set():
            if self.heartbeat is not None:
                self.heartbeat.beat()
            while True:                      # drain all pending control ops
                try:
                    op, payload = self.inbox.get_nowait()
                except queue.Empty:
                    break
                self._handle(op, payload)
            if self.eng.sched.idle:
                # an abort that idled the engine leaves its terminal event
                # pending — deliver it (and release the _subs entry) now
                self._fan_out(self.eng.drain_events())
                try:
                    op, payload = self.inbox.get(timeout=self.idle_poll_s)
                except queue.Empty:
                    continue
                self._handle(op, payload)
                continue
            self._fan_out(self.eng.step_events())


# --------------------------------------------------------------- HTTP layer
def _parse_completion_body(body: dict) -> _Submission:
    prompt = body.get("prompt")
    if (not isinstance(prompt, list) or not prompt
            or not all(isinstance(t, int) for t in prompt)):
        raise ValueError(
            "'prompt' must be a non-empty list of token ids (this server "
            "has no tokenizer)")
    temperature = float(body.get("temperature", 1.0))
    stop = body.get("stop", [])
    if isinstance(stop, int):
        stop = [stop]
    if not isinstance(stop, list) or not all(isinstance(t, int) for t in stop):
        raise ValueError("'stop' must be a token id or list of token ids")
    timeout = body.get("queue_timeout_s")
    if timeout is not None:
        timeout = float(timeout)
        if timeout <= 0:
            raise ValueError("'queue_timeout_s' must be > 0")
    return _Submission(
        tokens=list(prompt),
        max_new_tokens=int(body.get("max_tokens", 16)),
        sampling=SamplingParams(
            temperature=temperature,
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            greedy=temperature == 0.0),
        stop_token_ids=tuple(stop),
        ignore_eos=bool(body.get("ignore_eos", False)),
        priority=int(body.get("priority", 0)),
        queue_timeout_s=timeout)


def _choice(ev_or_tokens, finish_reason=None) -> dict:
    toks = (ev_or_tokens if isinstance(ev_or_tokens, list)
            else [ev_or_tokens])
    return {"index": 0,
            "token_ids": toks,
            "text": " ".join(map(str, toks)),
            "finish_reason": (finish_reason.value
                              if finish_reason is not None else None)}


class CompletionsHandler(BaseHTTPRequestHandler):
    """``/v1/completions`` (+ ``/v1/models``, ``/healthz``, ``/metrics``)."""
    protocol_version = "HTTP/1.1"

    def log_message(self, *args):            # keep benchmark/test output clean
        pass

    @property
    def worker(self) -> EngineWorker:
        return self.server.worker

    def _json(self, code: int, payload: dict,
              headers: dict | None = None):
        data = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):
        if self.path == "/healthz":
            self._healthz()
        elif self.path == "/metrics":
            self._metrics()
        elif self.path == "/v1/models":
            self._json(200, {"object": "list", "data": [
                {"id": self.server.model_name, "object": "model"}]})
        else:
            self._json(404, {"error": {"message": f"no route {self.path}"}})

    def _healthz(self):
        """Liveness + worker-heartbeat freshness.  With the watchdog armed
        (``stall_timeout_s``), a stale heartbeat turns this into a 503 so a
        scraper/load-balancer sees the wedged engine the same way in-flight
        clients do (DESIGN.md §15)."""
        hb = self.worker.heartbeat
        if hb is None:
            self._json(200, {"status": "ok", "watchdog": "disarmed"})
            return
        healthy = hb.healthy
        self._json(200 if healthy else 503, {
            "status": "ok" if healthy else "stalled",
            "watchdog": "armed",
            "heartbeat_stale_s": round(hb.stale_s, 6),
            "heartbeat_timeout_s": hb.timeout_s,
            "missed": hb.missed,
            "stalled_requests": self.worker.stalled_requests})

    def _metrics(self):
        """Prometheus text exposition (format 0.0.4) of the engine's
        registry.  The snapshot is read without pausing the worker — every
        sample is a plain float read, torn at worst by one step."""
        text = self.worker.eng.metrics.registry.expose()
        data = text.encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_POST(self):
        if self.path != "/v1/completions":
            self._json(404, {"error": {"message": f"no route {self.path}"}})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            sub = _parse_completion_body(body)
        except (ValueError, json.JSONDecodeError) as e:
            self._json(400, {"error": {"message": str(e),
                                       "type": "invalid_request_error"}})
            return
        try:
            rid = self.worker.submit(sub)
        except QueueFullError as e:          # bounded admission -> shed early
            self._json(429, {"error": {"message": str(e),
                                       "type": "overloaded_error"}},
                       headers={"Retry-After":
                                str(max(1, int(e.retry_after_s)))})
            return
        except (ValueError, queue.Empty) as e:
            self._json(400, {"error": {"message": str(e),
                                       "type": "invalid_request_error"}})
            return
        if body.get("stream", False):
            self._stream_response(rid, sub)
        else:
            self._blocking_response(rid, sub)

    # ------------------------------------------------------------ responses
    def _envelope(self, rid: int) -> dict:
        return {"id": f"cmpl-{rid}", "object": "text_completion",
                "created": int(self.worker.eng.clock.now()),
                "model": self.server.model_name}

    def _blocking_response(self, rid: int, sub: _Submission):
        toks: list[int] = []
        reason = None
        out = None
        while True:
            try:
                ev = sub.events.get(timeout=EVENT_TIMEOUT_S)
            except queue.Empty:
                # engine stalled: cancel the request so its reservation
                # frees, and tell the client instead of dropping the socket
                self.worker.abort(rid)
                self._json(504, {"error": {
                    "message": f"no token within {EVENT_TIMEOUT_S:.0f}s",
                    "type": "timeout_error"}})
                return
            if ev.token is not None:
                toks.append(ev.token)
            if ev.finish_reason is not None:
                reason = ev.finish_reason
                out = ev.output         # None on synthetic watchdog events
                break
        if reason in (FinishReason.SHED, FinishReason.STALL):
            # overload outcome: 503 + Retry-After; the SHED request never
            # produced a token, the STALL one may have partial output the
            # client opted not to stream
            self._json(503, {"error": {
                "message": f"request {reason.value} under overload",
                "type": "overloaded_error"}},
                headers={"Retry-After": "1"})
            return
        resp = self._envelope(rid)
        resp["choices"] = [_choice(toks, reason)]
        resp["usage"] = {
            "prompt_tokens": out.prompt_len, "completion_tokens": len(toks),
            "total_tokens": out.prompt_len + len(toks)}
        resp["metrics"] = {"ttft_s": out.ttft, "tpot_s": out.tpot,
                           "latency_s": out.latency}
        self._json(200, resp)

    def _stream_response(self, rid: int, sub: _Submission):
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        try:
            while True:
                ev = sub.events.get(timeout=EVENT_TIMEOUT_S)
                chunk = self._envelope(rid)
                chunk["choices"] = [_choice(
                    [ev.token] if ev.token is not None else [],
                    ev.finish_reason)]
                self.wfile.write(b"data: " + json.dumps(chunk).encode()
                                 + b"\n\n")
                self.wfile.flush()
                if ev.finish_reason is not None:
                    break
            self.wfile.write(b"data: [DONE]\n\n")
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            # client went away mid-stream: cancel the request so its slot /
            # paged reservation (and prefix refcounts) free immediately
            self.worker.abort(rid)
        except queue.Empty:
            self.worker.abort(rid)


class CompletionsServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr, handler, *, worker: EngineWorker,
                 model_name: str):
        super().__init__(addr, handler)
        self.worker = worker
        self.model_name = model_name

    def shutdown(self):
        super().shutdown()
        self.worker.shutdown()

    @property
    def port(self) -> int:
        return self.server_address[1]


def make_server(engine, host: str = "127.0.0.1", port: int = 0,
                model_name: str = "repro",
                stall_timeout_s: float | None = None) -> CompletionsServer:
    """Start the engine worker and bind the HTTP server (``port=0`` picks an
    ephemeral port — read it back from ``server.port``).  The caller runs
    ``server.serve_forever()``; ``server.shutdown()`` stops both.
    ``stall_timeout_s`` arms the worker watchdog (DESIGN.md §14)."""
    worker = EngineWorker(engine, stall_timeout_s=stall_timeout_s)
    worker.start()
    return CompletionsServer((host, port), CompletionsHandler,
                             worker=worker, model_name=model_name)
