"""Per-request step-span tracing with Chrome/Perfetto export (DESIGN.md §15).

A ``Tracer`` attached via ``EngineConfig.tracer`` records two kinds of
timeline, timestamped exclusively through the engine's injectable clock
(``serving/clock.py`` — under a ``ManualClock`` the exported trace is
byte-deterministic across runs):

* **request lifecycle spans** on one Perfetto track per request
  (pid ``PID_REQUESTS``, tid = rid): QUEUED → PREFILL → RUNNING →
  PREEMPTED → RESTORED-RUNNING → terminal instant (``finish`` with the
  ``FinishReason``).  Offload/restore page movement and injected faults
  (``serving/faults.py``) land as instant events on the same tracks.
* **engine step spans** on the engine track (pid ``PID_ENGINE``): one
  ``X`` slice per ``Engine.step`` carrying batch size, queue depth, and
  page-pool occupancy annotations; prefill slices carry the bucketed
  chunk length and page-reservation annotations.

Export is the Chrome ``trace_event`` JSON-object format (the one
``about:tracing`` and https://ui.perfetto.dev load directly):
``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with ``ts``/``dur`` in
microseconds, ``M`` metadata events naming every track, ``X`` complete
slices and scoped ``i`` instants.  ``validate_trace`` is the schema check
the tests and the CI artifact gate run over exported files.

Tracing is pure host-side bookkeeping: no device value is ever read for a
span (the engine's one device->host transfer per decode step is unchanged,
and greedy outputs are bit-identical with tracing on or off — both tested).
``Tracer(enabled=False)`` (or simply no tracer) is the opt-out; every
record call short-circuits on one attribute check.
"""
from __future__ import annotations

import json
from typing import Optional

PID_ENGINE = 1
PID_REQUESTS = 2
TID_STEPS = 0

# span/instant categories
CAT_STEP = "engine"
CAT_LIFECYCLE = "request"
CAT_FAULT = "fault"

_ALLOWED_PH = {"M", "X", "i"}


def _us(t: float) -> float:
    """Seconds -> integer-friendly microseconds (rounded to 0.1us so float
    repr stays stable and the export byte-deterministic)."""
    return round(float(t) * 1e6, 1)


class Tracer:
    """Collects trace events; one tracer serves one engine.

    The engine hands every timestamp in explicitly (read from its
    injectable clock) — the tracer itself never looks at a clock, which is
    what makes ManualClock runs reproduce byte-identical traces.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: list[dict] = []
        self._threads: dict[tuple[int, int], str] = {}
        self._processes: dict[int, str] = {PID_ENGINE: "engine",
                                           PID_REQUESTS: "requests"}
        self._open: dict[int, tuple[str, float, dict]] = {}  # rid -> state
        self._thread(PID_ENGINE, TID_STEPS, "steps")

    # ------------------------------------------------------------- primitives
    def _thread(self, pid: int, tid: int, name: str):
        self._threads.setdefault((pid, tid), name)

    def complete(self, name: str, cat: str, pid: int, tid: int,
                 t0: float, t1: float, **args):
        """One ``X`` slice [t0, t1] (seconds)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "X", "pid": pid, "tid": tid,
            "ts": _us(t0), "dur": max(0.0, _us(t1) - _us(t0)),
            "args": dict(args)})

    def instant(self, name: str, cat: str, pid: int, tid: int, t: float,
                **args):
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "pid": pid, "tid": tid, "ts": _us(t), "args": dict(args)})

    # ---------------------------------------------------------- engine hooks
    def step_span(self, t0: float, t1: float, **args):
        self.complete("step", CAT_STEP, PID_ENGINE, TID_STEPS, t0, t1,
                      **args)

    def prefill_span(self, rid: int, t0: float, t1: float, **args):
        """Prefill work slice on the engine track (chunk/bucket + page
        annotations) — the request's own PREFILL lifecycle span covers
        queue-exit to first token on its request track."""
        self.complete("prefill", CAT_STEP, PID_ENGINE, TID_STEPS, t0, t1,
                      rid=rid, **args)

    # speculative decoding (DESIGN.md §16): the two halves of one verify
    # step on the engine track — proposal (speculator host/draft-model
    # work) and the batched verify forward + accept/emit
    def propose_span(self, t0: float, t1: float, **args):
        self.complete("propose", CAT_STEP, PID_ENGINE, TID_STEPS, t0, t1,
                      **args)

    def verify_span(self, t0: float, t1: float, **args):
        self.complete("verify", CAT_STEP, PID_ENGINE, TID_STEPS, t0, t1,
                      **args)

    def request_state(self, rid: int, state: str, t: float, **args):
        """Move a request's lifecycle track to ``state`` at time ``t``:
        closes the previous state's span (if any) as an ``X`` slice and
        opens the new one.  ``args`` attach to the span being *opened*."""
        if not self.enabled:
            return
        self._thread(PID_REQUESTS, rid, f"req {rid}")
        prev = self._open.pop(rid, None)
        if prev is not None:
            pstate, t0, pargs = prev
            self.complete(pstate, CAT_LIFECYCLE, PID_REQUESTS, rid, t0, t,
                          **pargs)
        self._open[rid] = (state, t, dict(args))

    def request_end(self, rid: int, reason: str, t: float, **args):
        """Terminal transition: close the open span and drop an instant
        (``finish``) carrying the ``FinishReason``."""
        if not self.enabled:
            return
        self.request_state(rid, "_end", t)      # closes the open span
        self._open.pop(rid, None)
        self.instant("finish", CAT_LIFECYCLE, PID_REQUESTS, rid, t,
                     reason=reason, **args)

    def request_instant(self, rid: int, name: str, t: float, **args):
        if not self.enabled:
            return
        self._thread(PID_REQUESTS, rid, f"req {rid}")
        self.instant(name, CAT_LIFECYCLE, PID_REQUESTS, rid, t, **args)

    def fault_instant(self, kind: str, t: float, **args):
        """Injected faults (``serving/faults.py``) land on the engine track
        so overload post-mortems line them up against step spans."""
        self.instant(f"fault:{kind}", CAT_FAULT, PID_ENGINE, TID_STEPS, t,
                     **args)

    # ------------------------------------------------------------------ export
    def flush_open(self, t: float):
        """Close still-open lifecycle spans at ``t`` (end-of-run export of a
        trace whose requests never finished)."""
        for rid in sorted(self._open):
            state, t0, args = self._open.pop(rid)
            self.complete(state, CAT_LIFECYCLE, PID_REQUESTS, rid, t0, t,
                          **args)

    def to_dict(self) -> dict:
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": name}}
                for pid, name in sorted(self._processes.items())]
        meta += [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                  "args": {"name": name}}
                 for (pid, tid), name in sorted(self._threads.items())]
        return {"displayTimeUnit": "ms", "traceEvents": meta + self.events}

    def to_json(self) -> str:
        """Canonical serialization: sorted keys, no whitespace — two runs
        with the same ManualClock schedule serialize byte-identically."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path


# ------------------------------------------------------------------ validation
def validate_trace(obj) -> list[str]:
    """Schema check for an exported trace (dict or JSON string).  Returns a
    list of problems — empty means the trace is well-formed Chrome
    ``trace_event`` JSON that Perfetto/about:tracing loads without
    warnings: metadata names every referenced track, slices have
    non-negative ``ts``/``dur``, instants carry a scope, args are
    JSON-serializable."""
    problems: list[str] = []
    if isinstance(obj, (str, bytes)):
        try:
            obj = json.loads(obj)
        except json.JSONDecodeError as e:
            return [f"not valid JSON: {e}"]
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with 'traceEvents'"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    named: set[tuple[int, int]] = set()
    used: set[tuple[int, int]] = set()
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _ALLOWED_PH:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int) \
                or not isinstance(ev.get("tid"), int):
            problems.append(f"{where}: pid/tid must be ints")
            continue
        key = (ev["pid"], ev["tid"])
        if ph == "M":
            if ev["name"] == "thread_name":
                named.add(key)
            if not isinstance(ev.get("args", {}).get("name"), str):
                problems.append(f"{where}: metadata without args.name")
            continue
        used.add(key)
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X slice with bad dur {dur!r}")
        if ph == "i" and ev.get("s") not in ("g", "p", "t"):
            problems.append(f"{where}: instant without scope 's'")
        args = ev.get("args", {})
        if not isinstance(args, dict):
            problems.append(f"{where}: args must be an object")
        else:
            try:
                json.dumps(args)
            except (TypeError, ValueError):
                problems.append(f"{where}: args not JSON-serializable")
    for key in sorted(used - named):
        problems.append(f"track pid={key[0]} tid={key[1]} has events but no "
                        f"thread_name metadata")
    return problems


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


NULL_TRACER: Optional[Tracer] = None   # the documented "tracing off" value
