"""Token samplers: greedy / temperature / top-k / top-p, batched and jittable."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0              # 0 = off
    top_p: float = 1.0          # 1.0 = off
    greedy: bool = False


def sample(logits: jnp.ndarray, rng, params: SamplingParams) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,)."""
    if params.greedy or params.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / jnp.maximum(params.temperature, 1e-6)
    if params.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -params.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
