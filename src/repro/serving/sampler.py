"""Token samplers: greedy / temperature / top-k / top-p, batched and jittable."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0              # 0 = off
    top_p: float = 1.0          # 1.0 = off
    greedy: bool = False

    def validate(self, vocab_size: int | None = None):
        """Reject out-of-domain parameters at submit time with a clear
        message, instead of letting them fail (or silently misbehave) inside
        the jitted batched sampler.  Comparisons are written so NaN fails."""
        if not self.temperature >= 0.0:
            raise ValueError(
                f"temperature must be >= 0 (0 means greedy), got "
                f"{self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1] (1.0 disables), got {self.top_p}")
        if not self.top_k >= 0:
            raise ValueError(
                f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if vocab_size is not None and self.top_k >= vocab_size:
            raise ValueError(
                f"top_k must be < vocab size {vocab_size}, got {self.top_k}")


def sample(logits: jnp.ndarray, rng, params: SamplingParams) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,)."""
    if params.greedy or params.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / jnp.maximum(params.temperature, 1e-6)
    if params.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -params.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def sample_batched(logits: jnp.ndarray, keys, *, greedy: jnp.ndarray,
                   temps: jnp.ndarray, top_ks: jnp.ndarray,
                   top_ps: jnp.ndarray) -> jnp.ndarray:
    """Per-slot-parameterized sampling, fully on device and jittable.

    logits: (B, V); keys: (B,) PRNG key array; greedy: (B,) bool (true also
    for temperature==0); temps: (B,) > 0; top_ks: (B,) int32 (0 = off);
    top_ps: (B,) float (1.0 = off).  For float32 logits (what the model head
    always emits — ``LM._logits`` casts) row i reproduces exactly what
    ``sample(logits[i:i+1], keys[i], SamplingParams(...))`` returns — the
    engine's fused decode step relies on this equivalence (tested).  For
    lower-precision logits the f32 cast below can move cutoff boundaries
    relative to ``sample``'s native-dtype math.
    """
    v = logits.shape[-1]
    greedy_toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    lf = logits.astype(jnp.float32) / jnp.maximum(temps, 1e-6)[:, None]
    # top-k: k-th largest value per row as threshold (k=0 keeps everything)
    kth_idx = jnp.clip(v - top_ks, 0, v - 1)
    kth = jnp.take_along_axis(jnp.sort(lf, axis=-1), kth_idx[:, None], axis=-1)
    lf = jnp.where((top_ks[:, None] > 0) & (lf < kth), -jnp.inf, lf)
    # top-p on the post-top-k distribution (same op order as `sample`)
    sorted_desc = jnp.sort(lf, axis=-1)[:, ::-1]
    cum = jnp.cumsum(jax.nn.softmax(sorted_desc, axis=-1), axis=-1)
    cutoff_idx = jnp.clip(jnp.sum(cum < top_ps[:, None], axis=-1), 0, v - 1)
    cutoff = jnp.take_along_axis(sorted_desc, cutoff_idx[:, None], axis=-1)
    lf = jnp.where((top_ps[:, None] < 1.0) & (lf < cutoff), -jnp.inf, lf)

    sampled = jax.vmap(
        lambda key, row: jax.random.categorical(key, row[None, :], axis=-1)[0]
    )(keys, lf).astype(jnp.int32)
    return jnp.where(greedy, greedy_toks, sampled)
