"""Token samplers: greedy / temperature / top-k / top-p, batched and jittable."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0              # 0 = off
    top_p: float = 1.0          # 1.0 = off
    greedy: bool = False

    def validate(self, vocab_size: int | None = None):
        """Reject out-of-domain parameters at submit time with a clear
        message, instead of letting them fail (or silently misbehave) inside
        the jitted batched sampler.  Comparisons are written so NaN fails."""
        if not self.temperature >= 0.0:
            raise ValueError(
                f"temperature must be >= 0 (0 means greedy), got "
                f"{self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1] (1.0 disables), got {self.top_p}")
        if not self.top_k >= 0:
            raise ValueError(
                f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if vocab_size is not None and self.top_k >= vocab_size:
            raise ValueError(
                f"top_k must be < vocab size {vocab_size}, got {self.top_k}")


def sample(logits: jnp.ndarray, rng, params: SamplingParams) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,)."""
    if params.greedy or params.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / jnp.maximum(params.temperature, 1e-6)
    if params.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -params.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def filter_logits(logits: jnp.ndarray, temps: jnp.ndarray,
                  top_ks: jnp.ndarray, top_ps: jnp.ndarray) -> jnp.ndarray:
    """Temperature / top-k / top-p filtering over independent rows.

    logits: (N, V) any float dtype; temps: (N,) > 0; top_ks: (N,) int32
    (0 = off); top_ps: (N,) float (1.0 = off).  Returns f32 logits with
    ``-inf`` outside the per-row nucleus — ``softmax`` of the result is the
    filtered sampling distribution.  Same op order as ``sample``: top-p is
    computed on the post-top-k distribution.
    """
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32) / jnp.maximum(temps, 1e-6)[:, None]
    # top-k: k-th largest value per row as threshold (k=0 keeps everything)
    kth_idx = jnp.clip(v - top_ks, 0, v - 1)
    kth = jnp.take_along_axis(jnp.sort(lf, axis=-1), kth_idx[:, None], axis=-1)
    lf = jnp.where((top_ks[:, None] > 0) & (lf < kth), -jnp.inf, lf)
    sorted_desc = jnp.sort(lf, axis=-1)[:, ::-1]
    cum = jnp.cumsum(jax.nn.softmax(sorted_desc, axis=-1), axis=-1)
    cutoff_idx = jnp.clip(jnp.sum(cum < top_ps[:, None], axis=-1), 0, v - 1)
    cutoff = jnp.take_along_axis(sorted_desc, cutoff_idx[:, None], axis=-1)
    return jnp.where((top_ps[:, None] < 1.0) & (lf < cutoff), -jnp.inf, lf)


def sample_batched(logits: jnp.ndarray, keys, *, greedy: jnp.ndarray,
                   temps: jnp.ndarray, top_ks: jnp.ndarray,
                   top_ps: jnp.ndarray) -> jnp.ndarray:
    """Per-slot-parameterized sampling, fully on device and jittable.

    logits: (B, V); keys: (B,) PRNG key array; greedy: (B,) bool (true also
    for temperature==0); temps: (B,) > 0; top_ks: (B,) int32 (0 = off);
    top_ps: (B,) float (1.0 = off).  For float32 logits (what the model head
    always emits — ``LM._logits`` casts) row i reproduces exactly what
    ``sample(logits[i:i+1], keys[i], SamplingParams(...))`` returns — the
    engine's fused decode step relies on this equivalence (tested).  For
    lower-precision logits the f32 cast below can move cutoff boundaries
    relative to ``sample``'s native-dtype math.
    """
    greedy_toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = filter_logits(logits, temps, top_ks, top_ps)
    sampled = jax.vmap(
        lambda key, row: jax.random.categorical(key, row[None, :], axis=-1)[0]
    )(keys, lf).astype(jnp.int32)
    return jnp.where(greedy, greedy_toks, sampled)


def _emit_matrix(drafts: jnp.ndarray, n_acc: jnp.ndarray,
                 bonus: jnp.ndarray) -> jnp.ndarray:
    """(B, K) drafts + per-row bonus at position ``n_acc`` -> (B, K+1)
    emitted tokens (positions past ``n_acc`` zeroed)."""
    b, k = drafts.shape
    pos = jnp.arange(k + 1, dtype=jnp.int32)[None, :]
    drafts_p = jnp.pad(drafts, ((0, 0), (0, 1)))
    return jnp.where(
        pos < n_acc[:, None], drafts_p,
        jnp.where(pos == n_acc[:, None], bonus[:, None], 0)).astype(jnp.int32)


def accept_speculative(logits: jnp.ndarray, drafts: jnp.ndarray,
                       draft_lens: jnp.ndarray, keys=None, *,
                       greedy=None, temps=None, top_ks=None, top_ps=None,
                       draft_probs=None, all_greedy: bool = False,
                       greedy_tol: float | None = None):
    """Vectorized accept test for speculative decoding (DESIGN.md §16).

    logits: (B, K+1, V) target logits from the verify pass — position ``j``
    scores the token that follows ``j`` accepted drafts (position ``K`` is
    the bonus distribution when every draft accepts).  drafts: (B, K) int32;
    draft_lens: (B,) int32 in [0, K] (rows may propose fewer than K).

    Returns ``(n_acc, emitted)``: ``n_acc`` (B,) int32 accepted-draft counts
    and ``emitted`` (B, K+1) int32 where ``emitted[:, :n_acc + 1]`` are the
    committed tokens (accepted drafts plus one bonus/resample token) and the
    tail is zeroed.

    Three acceptance rules, mixed per row via ``greedy``:

    * greedy rows — longest prefix where each draft matches the target
      argmax; bonus is the argmax after the accepted prefix.  Bit-identical
      to plain greedy decode by construction.
    * sampled rows without ``draft_probs`` (model-free proposers) —
      *sample-and-match*: draw one token per position from the filtered
      target distribution (same math as ``sample_batched``) and accept
      drafts while they equal the draw.  The emitted tokens are the draws
      themselves, so the output is distributed exactly as ancestral
      sampling from the target for *any* proposal.
    * sampled rows with ``draft_probs`` (B, K, V) (draft-model proposers) —
      standard speculative rejection sampling: accept draft ``d_j`` with
      probability ``min(1, p(d_j) / q(d_j))``; on first rejection resample
      from the residual ``normalize(max(p - q, 0))``; when all drafts
      accept, sample the bonus from the target distribution.

    ``greedy_tol`` relaxes the greedy rule to *tolerance-aware* acceptance
    (ISSUE 10 satellite): a draft is kept when its target logit is within
    ``greedy_tol`` of the row maximum, instead of requiring the exact
    argmax.  The multi-token matmul lane and the single-token GEMV lane of
    the GPTQ kernels accumulate in different orders (~1e-7 apart on fp32
    logits — ROADMAP §spec), so near-tied argmaxes can flip between the
    fused multi-token step and a plain GEMV decode; a tolerance around that
    gap makes acceptance robust to it.  The bonus token stays the exact
    argmax, so 1-token chunks (plain decode rows) are unaffected.
    """
    b, s, v = logits.shape
    k = s - 1
    pos = jnp.arange(k, dtype=jnp.int32)[None, :]
    in_len = pos < draft_lens[:, None]

    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # (B, K+1)
    if greedy_tol is not None:
        lf32 = logits[:, :k].astype(jnp.float32)
        d_logit = jnp.take_along_axis(
            lf32, drafts[..., None].clip(0), axis=-1)[..., 0]
        g_match = (d_logit >= lf32.max(axis=-1) - greedy_tol) & in_len
    else:
        g_match = (drafts == tgt[:, :k]) & in_len
    g_acc = jnp.sum(jnp.cumprod(g_match.astype(jnp.int32), axis=1), axis=1)
    g_bonus = jnp.take_along_axis(tgt, g_acc[:, None], axis=1)[:, 0]
    g_emit = _emit_matrix(drafts, g_acc, g_bonus)
    if all_greedy:
        return g_acc, g_emit

    # filtered target distribution at every position, per-row params
    # broadcast across the K+1 verify positions
    rep = lambda a: jnp.repeat(a, s, axis=0)
    lf = filter_logits(logits.reshape(b * s, v), rep(temps), rep(top_ks),
                       rep(top_ps)).reshape(b, s, v)

    if draft_probs is None:
        # sample-and-match: one draw per position, independent keys.  A
        # 1-wide window (plain decode through the fused step) spends the
        # row key itself, reproducing ``sample``/``sample_batched`` exactly
        # — the engine's greedy-and-sampled parity tests rely on it.
        if s == 1:
            pos_keys = keys[:, None]
        else:
            pos_keys = jax.vmap(lambda key: jax.random.split(key, s))(keys)
        draw = jax.vmap(jax.vmap(
            lambda key, row: jax.random.categorical(key, row[None], axis=-1)[0]
        ))(pos_keys, lf).astype(jnp.int32)                     # (B, K+1)
        s_match = (drafts == draw[:, :k]) & in_len
        s_acc = jnp.sum(jnp.cumprod(s_match.astype(jnp.int32), axis=1), axis=1)
        s_emit = jnp.where(
            jnp.arange(s, dtype=jnp.int32)[None, :] <= s_acc[:, None],
            draw, 0)
    else:
        # rejection sampling against the draft distribution q
        p = jax.nn.softmax(lf, axis=-1)                        # (B, K+1, V)
        k_u, k_res = jax.vmap(lambda key: tuple(jax.random.split(key)))(keys)
        u = jax.vmap(lambda key: jax.random.uniform(key, (k,)))(k_u)
        p_d = jnp.take_along_axis(p[:, :k], drafts[..., None], axis=-1)[..., 0]
        q_d = jnp.take_along_axis(draft_probs, drafts[..., None],
                                  axis=-1)[..., 0]
        # u <= p/q without the divide (q_d == 0 -> accept iff p_d > 0)
        ok = (u * q_d <= p_d) & in_len
        s_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        p_at = jnp.take_along_axis(p, s_acc[:, None, None], axis=1)[:, 0]
        q_at = jnp.take_along_axis(
            draft_probs, jnp.minimum(s_acc, k - 1)[:, None, None],
            axis=1)[:, 0]
        rejected = s_acc < draft_lens
        res = jnp.where(rejected[:, None], jnp.clip(p_at - q_at, 0.0), p_at)
        norm = jnp.sum(res, axis=-1, keepdims=True)
        res = jnp.where(norm > 0, res / jnp.maximum(norm, 1e-20), p_at)
        final = jax.vmap(
            lambda key, row: jax.random.categorical(
                key, jnp.log(jnp.maximum(row, 1e-20))[None], axis=-1)[0]
        )(k_res, res).astype(jnp.int32)
        s_emit = _emit_matrix(drafts, s_acc, final)

    n_acc = jnp.where(greedy, g_acc, s_acc)
    emitted = jnp.where(greedy[:, None], g_emit, s_emit)
    return n_acc, emitted
