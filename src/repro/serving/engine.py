"""Serving engine: continuous batching over a slot cache with jitted
prefill (bucketed lengths) and a single fused decode+sample step — the vLLM
role in the paper's stack, adapted to TPU serving idioms (DESIGN.md §2).

The decode hot loop is sync-free: per-request sampling parameters are lowered
to per-slot device arrays (greedy flag, temperature, top-k/top-p, one PRNG
key per slot), empty slots are masked on device, and the whole
model-step + sample runs inside one ``jit``.  Exactly one device->host
transfer happens per decode step — the (B,) sampled-token vector — instead of
the seed's per-slot ``int()`` round-trips and host-side sampling loop.
Prefill admission writes the slot's cache slice with
``lax.dynamic_update_slice`` (one traced program for every slot index) rather
than rebuilding the full cache tree per admitted request.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.models import layers as L
from repro.serving import kv_cache as KV
from repro.serving.sampler import SamplingParams, sample, sample_batched
from repro.serving.scheduler import (Active, Finished, Request, Scheduler,
                                     bucket_len)


@dataclasses.dataclass
class EngineStats:
    tokens_generated: int = 0
    prefill_tokens: int = 0
    steps: int = 0
    wall_s: float = 0.0

    @property
    def decode_throughput(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0


class Engine:
    def __init__(self, model: LM, params, *, batch_slots: int = 8,
                 max_len: int = 512, kernels: L.KernelConfig = L.DEFAULT_KERNELS,
                 eos_id: int = 1, cache_dtype=jnp.float32, seed: int = 0):
        self.model = model
        self.params = params
        self.kernels = kernels
        self.eos_id = eos_id
        self.slots = KV.SlotCache(model, batch_slots, max_len, dtype=cache_dtype)
        self.sched = Scheduler()
        self.rng = jax.random.key(seed)
        self.stats = EngineStats()
        self._next_rid = 0

        self._decode = jax.jit(
            functools.partial(self._decode_impl, self.model, self.kernels),
            static_argnames=("all_greedy",))
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl, self.model, self.kernels))
        self._read_slot = jax.jit(self._read_slot_impl)
        self._write_slot = jax.jit(self._write_slot_impl)

    # ------------------------------------------------------------ jitted fns
    @staticmethod
    def _decode_impl(model, kernels, params, tokens, cache, seq_lens, live,
                     greedy, temps, top_ks, top_ps, keys, *,
                     all_greedy: bool = False):
        """Fused decode step: model forward + per-slot-parameterized sampling.

        All sampling state arrives as per-slot arrays so one trace serves
        every mix of greedy/temperature/top-k/top-p requests; ``all_greedy``
        is a static host-known flag selecting an argmax-only second trace for
        the common all-greedy batch — the sampling operands arrive as None
        there (nothing staged, no rng split, no sort/softmax machinery).
        Dead slots (``live == False``) keep seq_lens at 0 and emit token 0
        (never read).
        """
        logits, cache, seq_lens = model.decode_step(
            params, tokens, cache, seq_lens, kernels=kernels)
        if all_greedy:
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            toks = sample_batched(logits, keys, greedy=greedy, temps=temps,
                                  top_ks=top_ks, top_ps=top_ps)
        toks = jnp.where(live, toks, 0)
        seq_lens = jnp.where(live, seq_lens, 0)
        return toks, cache, seq_lens

    @staticmethod
    def _prefill_impl(model, kernels, params, tokens, length, cache, seq_lens):
        # tokens right-padded to a bucket; `length` is the true prompt length.
        lengths = jnp.full((tokens.shape[0],), length, jnp.int32)
        logits, cache, seq_lens = model.prefill(
            params, {"tokens": tokens}, cache, seq_lens, kernels=kernels,
            true_lengths=lengths)   # index within the text block
        return logits, cache, seq_lens - (tokens.shape[1] - length)

    @staticmethod
    def _read_slot_impl(cache, slot):
        """Slice one slot's cache sub-tree (batch axis 1; traced slot index,
        so every slot shares a single compiled program)."""
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=1)
            if x.ndim >= 2 else x, cache)

    @staticmethod
    def _write_slot_impl(cache, sub, slot):
        """Write a prefilled sub-tree back into the slot via
        ``dynamic_update_slice`` — no whole-cache-tree rebuild per admit."""
        return jax.tree_util.tree_map(
            lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), slot, axis=1)
            if full.ndim >= 2 else s, cache, sub)

    # -------------------------------------------------------------- lifecycle
    def submit(self, tokens: list[int], max_new_tokens: int = 32,
               sampling: SamplingParams = SamplingParams(greedy=True)) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(Request(rid=rid, tokens=list(tokens),
                                  max_new_tokens=max_new_tokens,
                                  sampling=sampling, arrival=time.time()))
        return rid

    def _admit(self, finished: list[Finished]):
        for req in self.sched.admit(self.slots.num_free):
            slot = self.slots.alloc()
            assert slot is not None
            a = self.sched.activate(req, slot)
            # bucketed prefill on the slot's cache slice. Recurrent state
            # (SSM) and ring caches (SWA) are polluted by padded tokens ->
            # exact-length prefill for those families (one compile per length)
            cfg = self.model.cfg
            paddable = cfg.family not in ("ssm", "hybrid") and not cfg.sliding_window
            blen = bucket_len(len(req.tokens)) if paddable else len(req.tokens)
            toks = np.zeros((1, blen), np.int32)
            toks[0, :len(req.tokens)] = req.tokens
            slot_idx = jnp.asarray(slot, jnp.int32)
            sub_cache = self._read_slot(self.slots.cache, slot_idx)
            sub_lens = jnp.zeros((1,), jnp.int32)
            logits, sub_cache, sub_lens = self._prefill(
                self.params, jnp.asarray(toks), len(req.tokens), sub_cache,
                sub_lens)
            # prefill wrote positions [0, blen); real length excludes padding
            self.slots.cache = self._write_slot(self.slots.cache, sub_cache,
                                                slot_idx)
            self.slots.seq_lens = self.slots.seq_lens.at[slot].set(sub_lens[0])
            self.stats.prefill_tokens += len(req.tokens)
            # sample the first generated token from the prefill logits
            self.rng, k = jax.random.split(self.rng)
            tok = int(sample(logits, k, req.sampling)[0])
            a.t_first_token = time.time()
            a.output.append(tok)
            if tok == self.eos_id or len(a.output) >= req.max_new_tokens:
                self._finish(slot, finished)

    def _finish(self, slot: int, finished: list[Finished]):
        a = self.sched.retire(slot)
        self.slots.free(slot)
        finished.append(Finished(
            rid=a.req.rid, prompt_len=len(a.req.tokens), output=a.output,
            arrival=a.req.arrival, t_first_token=a.t_first_token,
            t_done=time.time()))

    def step(self) -> list[Finished]:
        """One engine iteration: admissions + one fused decode+sample step."""
        finished: list[Finished] = []
        self._admit(finished)
        if not self.sched.active:
            return finished
        # host-side staging: last tokens + per-slot sampling arrays (numpy,
        # no device round-trips)
        bs = self.slots.batch_slots
        tokens = np.zeros((bs, 1), np.int32)
        live = np.zeros((bs,), np.bool_)
        greedy = np.ones((bs,), np.bool_)
        temps = np.ones((bs,), np.float32)
        top_ks = np.zeros((bs,), np.int32)
        top_ps = np.ones((bs,), np.float32)
        for slot, a in self.sched.active.items():
            sp = a.req.sampling
            tokens[slot, 0] = a.output[-1] if a.output else a.req.tokens[-1]
            live[slot] = True
            greedy[slot] = sp.greedy or sp.temperature == 0.0
            temps[slot] = sp.temperature if sp.temperature > 0.0 else 1.0
            top_ks[slot] = sp.top_k
            top_ps[slot] = sp.top_p
        all_greedy = bool(greedy.all())
        if all_greedy:
            # argmax-only trace: no rng consumption, no sampling operands
            samp = (None, None, None, None, None)
        else:
            self.rng, sub = jax.random.split(self.rng)
            samp = (jnp.asarray(greedy), jnp.asarray(temps),
                    jnp.asarray(top_ks), jnp.asarray(top_ps),
                    jax.random.split(sub, bs))
        toks_dev, self.slots.cache, self.slots.seq_lens = self._decode(
            self.params, jnp.asarray(tokens), self.slots.cache,
            self.slots.seq_lens, jnp.asarray(live), *samp,
            all_greedy=all_greedy)
        # the single device->host transfer of the decode loop
        toks = jax.device_get(toks_dev).tolist()
        self.stats.tokens_generated += int(live.sum())
        self.stats.steps += 1
        for s in sorted(self.sched.active):
            a = self.sched.active[s]
            tok = toks[s]
            a.output.append(tok)
            if tok == self.eos_id or len(a.output) >= a.req.max_new_tokens:
                self._finish(s, finished)
        return finished

    def run(self, *, max_steps: int = 10_000) -> list[Finished]:
        """Drain the queue; returns finished requests with latency stats."""
        t0 = time.time()
        out: list[Finished] = []
        steps = 0
        while not self.sched.idle and steps < max_steps:
            out.extend(self.step())
            steps += 1
        self.stats.wall_s += time.time() - t0
        return out
