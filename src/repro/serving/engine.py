"""Serving engine: continuous batching over a slot cache with jitted
prefill (bucketed lengths) and a single fixed-shape decode step — the vLLM
role in the paper's stack, adapted to TPU serving idioms (DESIGN.md §2).

The decode step always runs the full slot batch; empty slots are masked by
seq_lens == 0 and a live-mask on sampling.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.models import layers as L
from repro.serving import kv_cache as KV
from repro.serving.sampler import SamplingParams, sample
from repro.serving.scheduler import (Active, Finished, Request, Scheduler,
                                     bucket_len)


@dataclasses.dataclass
class EngineStats:
    tokens_generated: int = 0
    prefill_tokens: int = 0
    steps: int = 0
    wall_s: float = 0.0

    @property
    def decode_throughput(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0


class Engine:
    def __init__(self, model: LM, params, *, batch_slots: int = 8,
                 max_len: int = 512, kernels: L.KernelConfig = L.DEFAULT_KERNELS,
                 eos_id: int = 1, cache_dtype=jnp.float32, seed: int = 0):
        self.model = model
        self.params = params
        self.kernels = kernels
        self.eos_id = eos_id
        self.slots = KV.SlotCache(model, batch_slots, max_len, dtype=cache_dtype)
        self.sched = Scheduler()
        self.rng = jax.random.key(seed)
        self.stats = EngineStats()
        self._next_rid = 0

        self._decode = jax.jit(
            functools.partial(self._decode_impl, self.model, self.kernels))
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl, self.model, self.kernels))

    # ------------------------------------------------------------ jitted fns
    @staticmethod
    def _decode_impl(model, kernels, params, tokens, cache, seq_lens):
        logits, cache, seq_lens = model.decode_step(
            params, tokens, cache, seq_lens, kernels=kernels)
        return logits, cache, seq_lens

    @staticmethod
    def _prefill_impl(model, kernels, params, tokens, length, cache, seq_lens):
        # tokens right-padded to a bucket; `length` is the true prompt length.
        lengths = jnp.full((tokens.shape[0],), length, jnp.int32)
        logits, cache, seq_lens = model.prefill(
            params, {"tokens": tokens}, cache, seq_lens, kernels=kernels,
            true_lengths=lengths)   # index within the text block
        return logits, cache, seq_lens - (tokens.shape[1] - length)

    # -------------------------------------------------------------- lifecycle
    def submit(self, tokens: list[int], max_new_tokens: int = 32,
               sampling: SamplingParams = SamplingParams(greedy=True)) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(Request(rid=rid, tokens=list(tokens),
                                  max_new_tokens=max_new_tokens,
                                  sampling=sampling, arrival=time.time()))
        return rid

    def _admit(self, finished: list[Finished]):
        for req in self.sched.admit(self.slots.num_free):
            slot = self.slots.alloc()
            assert slot is not None
            a = self.sched.activate(req, slot)
            # bucketed prefill on the slot's cache slice. Recurrent state
            # (SSM) and ring caches (SWA) are polluted by padded tokens ->
            # exact-length prefill for those families (one compile per length)
            cfg = self.model.cfg
            paddable = cfg.family not in ("ssm", "hybrid") and not cfg.sliding_window
            blen = bucket_len(len(req.tokens)) if paddable else len(req.tokens)
            toks = np.zeros((1, blen), np.int32)
            toks[0, :len(req.tokens)] = req.tokens
            sub_cache = jax.tree_util.tree_map(
                lambda x: x[:, slot:slot + 1] if x.ndim >= 2 else x,
                self.slots.cache)
            sub_lens = jnp.zeros((1,), jnp.int32)
            logits, sub_cache, sub_lens = self._prefill(
                self.params, jnp.asarray(toks), len(req.tokens), sub_cache,
                sub_lens)
            # prefill wrote positions [0, blen); real length excludes padding
            self.slots.cache = jax.tree_util.tree_map(
                lambda full, sub: full.at[:, slot:slot + 1].set(sub)
                if full.ndim >= 2 else sub,
                self.slots.cache, sub_cache)
            self.slots.seq_lens = self.slots.seq_lens.at[slot].set(
                int(sub_lens[0]))
            self.stats.prefill_tokens += len(req.tokens)
            # sample the first generated token from the prefill logits
            self.rng, k = jax.random.split(self.rng)
            tok = int(sample(logits, k, req.sampling)[0])
            a.t_first_token = time.time()
            a.output.append(tok)
            if tok == self.eos_id or len(a.output) >= req.max_new_tokens:
                self._finish(slot, finished)

    def _finish(self, slot: int, finished: list[Finished]):
        a = self.sched.retire(slot)
        self.slots.free(slot)
        finished.append(Finished(
            rid=a.req.rid, prompt_len=len(a.req.tokens), output=a.output,
            arrival=a.req.arrival, t_first_token=a.t_first_token,
            t_done=time.time()))

    def step(self) -> list[Finished]:
        """One engine iteration: admissions + one batched decode step."""
        finished: list[Finished] = []
        self._admit(finished)
        if not self.sched.active:
            return finished
        # batched decode over every slot (empty slots masked via live set)
        tokens = np.zeros((self.slots.batch_slots, 1), np.int32)
        for slot, a in self.sched.active.items():
            tokens[slot, 0] = a.output[-1] if a.output else a.req.tokens[-1]
        logits, self.slots.cache, self.slots.seq_lens = self._decode(
            self.params, jnp.asarray(tokens), self.slots.cache,
            self.slots.seq_lens)
        # non-live slots advanced seq_lens too; reset them
        live = sorted(self.sched.active)
        dead = [s for s in range(self.slots.batch_slots) if s not in live]
        if dead:
            self.slots.seq_lens = self.slots.seq_lens.at[jnp.asarray(dead)].set(0)
        self.rng, k = jax.random.split(self.rng)
        # per-request sampling params can differ; group greedy vs sampled
        toks = {}
        greedy_ids = [s for s in live if self.sched.active[s].req.sampling.greedy]
        other = [s for s in live if s not in greedy_ids]
        if greedy_ids:
            g = jnp.argmax(logits[jnp.asarray(greedy_ids)], axis=-1)
            for i, s in enumerate(greedy_ids):
                toks[s] = int(g[i])
        for s in other:
            self.rng, k2 = jax.random.split(self.rng)
            toks[s] = int(sample(logits[s:s + 1], k2,
                                 self.sched.active[s].req.sampling)[0])
        self.stats.tokens_generated += len(live)
        self.stats.steps += 1
        for s in live:
            a = self.sched.active[s]
            a.output.append(toks[s])
            if toks[s] == self.eos_id or len(a.output) >= a.req.max_new_tokens:
                self._finish(s, finished)
        return finished

    def run(self, *, max_steps: int = 10_000) -> list[Finished]:
        """Drain the queue; returns finished requests with latency stats."""
        t0 = time.time()
        out: list[Finished] = []
        steps = 0
        while not self.sched.idle and steps < max_steps:
            out.extend(self.step())
            steps += 1
        self.stats.wall_s += time.time() - t0
        return out
