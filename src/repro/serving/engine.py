"""Serving engine: continuous batching through ONE token-budgeted jitted
program per step — the vLLM role in the paper's stack (DESIGN.md §2, §10,
§11, §18).

The public surface is the request lifecycle API (``serving/api.py``):

* ``Engine(model, params, EngineConfig(...))`` — construction is
  single-sourced through ``EngineConfig``; the old 10-kwarg constructor
  survives as a deprecated shim (gated by ``tests/test_lint.py``).
* ``submit()`` validates at admission time (slot/page capacity,
  ``SamplingParams`` domains) and takes per-request stop criteria
  (``stop_token_ids``, ``ignore_eos``, ``max_new_tokens``).
* ``generate(prompts)`` — blocking convenience, returns ``RequestOutput``s
  with per-request ``ttft``/``tpot``/``finish_reason``.
* ``stream()`` — an iterator that pumps ``step()`` and yields per-token
  ``StreamEvent``s across *all* in-flight requests (continuous batching
  preserved); terminal events carry the ``RequestOutput``.
* ``abort(rid)`` — cancels a queued or in-flight request, freeing its slot
  or paged reservation (including prefix-cache refcounts) immediately.
* Requests move ``QUEUED → PREFILL → RUNNING → FINISHED | ABORTED`` — plus
  ``PREEMPTED`` and back under overload (``RequestState``);
  ``launch/serve.py --serve`` exposes the whole thing as an OpenAI-style
  ``/v1/completions`` HTTP endpoint with SSE streaming
  (``serving/http_api.py``).

Overload resilience (DESIGN.md §14): requests carry a ``priority`` class —
on the paged layout a higher class that cannot reserve pages preempts the
lowest/most-recent victim (its private pages are checkpointed to host
memory via ``PagedCache.offload`` and restored later, greedy
token-identical); ``EngineConfig.max_queued`` bounds the wait queue
(``QueueFullError`` → HTTP 429) and per-request queue deadlines shed
unadmitted requests (``FinishReason.SHED`` → HTTP 503).  All deadline
logic reads an injectable clock (``serving/clock.py``) and a
``FaultInjector`` (``serving/faults.py``) can deterministically inject
page exhaustion, stalls and aborts at chosen steps.

Two cache layouts, selected by ``EngineConfig.cache`` (default: the
``KernelConfig.cache_layout`` enum):

* ``"slot"`` — the model's native contiguous cache, fixed ``max_len`` per
  decode slot; bucketed prefill lengths (bounded jit recompiles).
* ``"paged"`` — the PagedAttention layout: fixed-size KV pages of a shared
  physical pool addressed through a device block table
  (``serving/kv_cache.py::PagedCache``), page-budget admission that reserves
  the full prompt+decode footprint up front (generation can never hit pool
  exhaustion mid-flight), a hashed-prefix cache (prefix-hit requests prefill
  only their suffix against the reused pages), and the Pallas paged
  kernels on *both* hot paths — decode and the chunked paged-prefill
  kernel, so no gathered KV copy is ever materialized.  Prefill is
  bucketed like the slot path — padded positions' page writes are routed
  to the null page (``write_lens``), so recompiles stay bounded by the
  bucket set.

Fused-step execution (ISSUE 10, DESIGN.md §18): every engine step is one
invocation of ``_fused_step_impl`` over a token-budgeted batch in which each
row is a ``(seq, chunk_start=seq_lens, chunk_len)`` span of its sequence —
plain decode is a 1-token chunk, chunked prefill a budget-sized chunk, and
speculative verify a (k+1)-token chunk.  Admission only *reserves* cache
space (pages / a slot); the prompt then streams into the cache as chunks
dealt by ``Scheduler.plan_chunks`` under ``EngineConfig.max_step_tokens``,
so a long prompt can no longer stall concurrent decodes for its whole
prefill — the bounded-TTFT-under-load payoff BENCH_serving.json's
``chunked_prefill`` section measures.  The step width is bucketed
(1, k+1, then the prefill buckets) so jit recompiles stay bounded under
mixed traffic.

The hot loop is sync-free in both layouts: per-request sampling parameters
are lowered to per-row device arrays (greedy flag, temperature, top-k/top-p,
one PRNG key per row), empty rows are masked on device, and the whole
model-step + accept/sample runs inside one ``jit``.  Exactly one
device->host transfer happens per step — the packed (B, K+2) int32 matrix
``[n_emitted | emitted tokens...]`` (K=0 without speculation).
"""
from __future__ import annotations

import functools
import json
import os
import warnings
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.serving import clock as CLK
from repro.serving import kv_cache as KV
from repro.serving import kv_quant as KQ
from repro.serving import parallel as PL
from repro.serving import spec_decode as SD
from repro.serving.api import (EngineConfig, FinishReason, QueueFullError,
                               RequestOutput, RequestState, StreamEvent)
from repro.serving.metrics import EngineMetrics, make_engine_metrics
from repro.serving.sampler import (SamplingParams, accept_speculative,
                                   sample)
from repro.serving.scheduler import (PREFILL_BUCKETS, Active, Request,
                                     Scheduler, bucket_len)


class EngineStats:
    """Read-view over the engine's metrics registry (DESIGN.md §15).

    The attribute surface predates the registry (ad-hoc dataclass counting)
    and is kept verbatim so existing callers and the BENCH_serving.json
    schema don't move; every value now reads straight out of the same
    counters ``GET /metrics`` exposes — the two can never disagree.  With
    ``EngineConfig(metrics=False)`` all values read 0.
    """

    def __init__(self, metrics: EngineMetrics):
        self._m = metrics

    # counters ---------------------------------------------------------------
    @property
    def tokens_generated(self) -> int:
        return int(self._m.tokens_generated.value)

    @property
    def prefill_tokens(self) -> int:
        return int(self._m.prefill_tokens.value)

    @property
    def steps(self) -> int:
        return int(self._m.steps.value)

    @property
    def wall_s(self) -> float:
        """Clock seconds spent inside ``Engine.step`` (the injectable
        clock) — accumulated per step, so direct ``step()`` pumps (the HTTP
        worker, the overload bench) are accounted exactly like ``run()``."""
        return float(self._m.wall_seconds.value)

    # paged layout: pages/tokens served from the hashed-prefix cache instead
    # of being re-prefilled
    @property
    def prefix_hit_pages(self) -> int:
        return int(self._m.prefix_hit_pages.value)

    @property
    def prefix_hit_tokens(self) -> int:
        return int(self._m.prefix_hit_tokens.value)

    # deepest concurrent batch ever admitted — the number int8 KV moves by
    # widening the page pool under a fixed byte budget (DESIGN.md §12)
    @property
    def peak_active(self) -> int:
        return int(self._m.peak_active.value)

    # ---- overload resilience (DESIGN.md §14) ----
    @property
    def preemptions(self) -> int:
        return int(self._m.preemptions.value)

    @property
    def offloaded_pages(self) -> int:
        return int(self._m.offloaded_pages.value)

    @property
    def offloaded_bytes(self) -> int:
        return int(self._m.offloaded_bytes.value)

    @property
    def restored_pages(self) -> int:
        return int(self._m.restored_pages.value)

    @property
    def rejected_submits(self) -> int:
        return int(self._m.rejected_submits.value)

    @property
    def deferred_admissions(self) -> int:
        return int(self._m.deferred_admissions.value)

    @property
    def shed_requests(self) -> int:
        return int(self._m.shed_requests.value)

    @property
    def decode_throughput(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0

    # ---- speculative decoding (DESIGN.md §16) ----
    @property
    def spec_proposed(self) -> int:
        return int(self._m.spec_proposed.value)

    @property
    def spec_accepted(self) -> int:
        return int(self._m.spec_accepted.value)

    @property
    def spec_verify_steps(self) -> int:
        return int(self._m.spec_verify_steps.value)

    @property
    def acceptance_rate(self) -> float:
        """Accepted / proposed draft tokens (0.0 before any proposal)."""
        p = self.spec_proposed
        return self.spec_accepted / p if p else 0.0

    @property
    def tokens_per_step(self) -> float:
        """Decode tokens emitted per engine step — 1.0 for plain decode,
        up to k+1 under speculation.  The multi-token-step-aware
        denominator for throughput accounting: tpot and tok/s derive from
        *emitted tokens* (see ``RequestOutput.tpot``), never from step
        counts, so BENCH_serving.json stays comparable across spec
        on/off."""
        s = self.steps
        return self.tokens_generated / s if s else 0.0

    def __repr__(self) -> str:
        fields = ("tokens_generated", "prefill_tokens", "steps", "wall_s",
                  "prefix_hit_pages", "prefix_hit_tokens", "peak_active",
                  "preemptions", "offloaded_pages", "offloaded_bytes",
                  "restored_pages", "rejected_submits",
                  "deferred_admissions", "shed_requests", "spec_proposed",
                  "spec_accepted")
        inner = ", ".join(f"{f}={getattr(self, f)!r}" for f in fields)
        return f"EngineStats({inner})"


_UNSET = object()


class Engine:
    def __init__(self, model: LM, params,
                 config: Optional[EngineConfig] = None, *,
                 batch_slots=_UNSET, max_len=_UNSET, kernels=_UNSET,
                 eos_id=_UNSET, cache_dtype=_UNSET, seed=_UNSET,
                 cache=_UNSET, page_size=_UNSET, num_pages=_UNSET):
        legacy = {k: v for k, v in dict(
            batch_slots=batch_slots, max_len=max_len, kernels=kernels,
            eos_id=eos_id, cache_dtype=cache_dtype, seed=seed, cache=cache,
            page_size=page_size, num_pages=num_pages).items()
            if v is not _UNSET}
        if config is not None and legacy:
            raise TypeError(
                f"pass either an EngineConfig or legacy kwargs, not both "
                f"(got config and {sorted(legacy)})")
        if config is None:
            # deprecated shim: the pre-EngineConfig kwarg constructor.
            # tests/test_lint.py gates in-repo (non-test) callers off it.
            if legacy:
                warnings.warn(
                    "Engine(**kwargs) is deprecated; pass "
                    "Engine(model, params, EngineConfig(...))",
                    DeprecationWarning, stacklevel=2)
            config = EngineConfig(**legacy)
        self.config = config
        self.model = model
        self.params = params
        self.kernels = config.kernels
        self.eos_id = config.eos_id
        self.sched = Scheduler()
        self.rng = jax.random.key(config.seed)
        self.clock = config.clock if config.clock is not None \
            else CLK.SYSTEM_CLOCK
        self.faults = config.faults
        self.tracer = config.tracer
        self._step_no = 0
        self._next_rid = 0
        self._requests: dict[int, Request] = {}
        self._events: list[StreamEvent] = []
        # rid -> RestoredSeq for restores committed by _reserve_paged but
        # not yet resumed by _admit_paged (one admission pass apart)
        self._pending_restores: dict[int, KV.RestoredSeq] = {}
        self._admit_round: list[Request] = []
        kvq = config.kv_quant            # normalized by EngineConfig
        if kvq is not None and not kvq.quantized:
            # fp passthrough is just another way to spell the cache dtype
            cache_dtype = kvq.jnp_dtype
            kvq = None
        elif config.cache_dtype is not None:
            cache_dtype = config.cache_dtype
        else:
            cache_dtype = KV.DEFAULT_CACHE_DTYPE
        self.kv_quant = kvq
        # what the cache payloads are stored as (int8 when quantized)
        self.cache_dtype = jnp.dtype(jnp.int8) if kvq is not None \
            else jnp.dtype(cache_dtype)
        batch_slots, max_len = config.batch_slots, config.max_len
        page_size, num_pages = config.page_size, config.num_pages

        layout = config.cache if config.cache is not None \
            else config.kernels.cache_layout
        self.layout = getattr(layout, "value", layout)
        if self.layout not in ("slot", "paged"):
            raise ValueError(f"unknown cache layout {layout!r}")
        if config.page_pool_bytes is not None and self.layout != "paged":
            raise ValueError(
                "page_pool_bytes applies to the paged cache layout only")

        # ---- tensor parallelism (DESIGN.md §17) ----
        self.tp = PL.mesh_size(config.mesh_shape)
        self._tp_ctx = None
        if self.tp > 1:
            if self.layout != "paged":
                raise ValueError(
                    "tensor-parallel serving shards the KV page pools — "
                    "the slot layout is single-device (cache='paged')")
            # validates head divisibility / GQA-only / act-order and builds
            # the mesh + local model + parameter PartitionSpecs
            self._tp_ctx = PL.build_tp_context(model, params, self.tp,
                                               config.tp_axis)

        # observability (DESIGN.md §15): one registry per engine, stamped
        # with the cache layout + kv-quant mode as constant labels;
        # EngineStats is a thin read-view over the same counters /metrics
        # exposes, so the two can never disagree
        kv_mode = kvq.dtype if kvq is not None \
            else jnp.dtype(cache_dtype).name
        self.metrics = make_engine_metrics(self.layout, kv_mode,
                                           enabled=config.metrics)
        self.stats = EngineStats(self.metrics)

        if self.layout == "paged":
            cfg = model.cfg
            max_pages = -(-max_len // page_size)
            if config.page_pool_bytes is not None:
                # byte-budget-derived pool: int8 KV buys ~2x (vs bf16) / ~4x
                # (vs fp32) the pages — i.e. deeper continuous batching.
                # Under tensor parallelism the budget is *per device*: each
                # device's pool holds its num_kv_heads/tp head-slice, so the
                # same byte budget buys tp× the pages (capacity scales with
                # devices, the whole point of DESIGN.md §17)
                num_pages = KQ.num_pages_for_budget(
                    config.page_pool_bytes, cfg.num_layers,
                    cfg.num_kv_heads // self.tp,
                    cfg.head_dim, page_size, dtype=cache_dtype, kv_quant=kvq)
            elif num_pages is None:
                num_pages = KQ.default_num_pages(batch_slots, max_len,
                                                 page_size)
            # bookkeeping-only manager: page payloads live in the model cache
            # tree below; the manager owns the device block table + free lists
            self.pc = KV.PagedCache(
                num_pages=num_pages, page_size=page_size,
                n_layers=cfg.num_layers, kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim, dtype=cache_dtype,
                max_seqs=batch_slots, max_pages=max_pages, alloc_pools=False,
                kv_quant=kvq)
            # raises for stacks paging can't serve (SSM/SWA/MLA/meta tokens)
            self.cache = model.init_paged_cache(num_pages, page_size,
                                                dtype=cache_dtype,
                                                kv_quant=kvq)
            if self._tp_ctx is not None:
                # head-shard the pools and the GPTQ weights; page *ids*
                # stay global, so the PagedCache bookkeeping above (free
                # lists, refcounts, COW, prefix index) is unchanged
                self.cache = PL.shard_cache(self._tp_ctx, self.cache)
                self.params = PL.shard_params(self._tp_ctx, self.params)
            # per-device pool accounting for the device-labeled gauges
            self.metrics.configure_devices(
                self.tp,
                KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads // self.tp,
                              cfg.head_dim, page_size, dtype=cache_dtype,
                              kv_quant=kvq) * (num_pages + 1))
            self.slots = None
        else:
            self.slots = KV.SlotCache(model, batch_slots, max_len,
                                      dtype=cache_dtype, kv_quant=kvq)
            self.pc = None
        self.batch_rows = batch_slots
        self.max_len = max_len

        # ---- speculative decoding (DESIGN.md §16) ----
        self._spec: Optional[SD.Speculator] = None
        if config.speculation is not None:
            cfg = model.cfg
            # rollback-by-not-advancing-seq_lens needs positional KV that
            # rejected writes can be abandoned in; recurrent (SSM) state and
            # ring (SWA) caches are mutated destructively by every token
            if cfg.family in ("ssm", "hybrid") or cfg.sliding_window \
                    or cfg.meta_tokens or cfg.attn_type != "gqa":
                raise ValueError(
                    "speculative decoding requires a full-attention GQA "
                    "stack with positional KV (no SSM/sliding-window/MLA/"
                    f"meta tokens), got family={cfg.family!r} "
                    f"attn_type={cfg.attn_type!r}")
            self._spec = SD.make_speculator(config.speculation, model,
                                            config, kernels=self.kernels)

        # Chunked prefill rides the same write-masked multi-token path as
        # spec-verify, so it carries the same family restriction; the other
        # slot-layout families (SSM/SWA/hybrid/meta — paging already rejects
        # them) keep the legacy inline whole-prompt prefill at admission and
        # run their decodes as 1-token chunks of the fused step.
        cfg = model.cfg
        self._chunked = (cfg.family not in ("ssm", "hybrid")
                         and not cfg.sliding_window and not cfg.meta_tokens
                         and cfg.attn_type == "gqa")
        # fused-step width buckets: 1 (pure decode), k+1 (verify), then the
        # prefill buckets — bounds recompiles under mixed traffic
        k1 = self._spec.k + 1 if self._spec is not None else 1
        self._width_buckets = tuple(sorted({1, k1, *PREFILL_BUCKETS}))

        # donate the cache tree (and seq_lens) so XLA updates the KV pools
        # in place instead of copying the whole pool every step — the
        # engine reassigns them from the jit results and keeps no other
        # reference.  CPU has no donation support (it would only warn), so
        # gate on the backend.
        cpu = jax.default_backend() == "cpu"
        tol = (config.speculation.greedy_accept_tol
               if config.speculation is not None else None)
        # the ONE jitted program every step runs (ISSUE 10): decode,
        # chunked prefill and spec-verify are all chunk rows of it
        impl = functools.partial(self._fused_step_impl, greedy_tol=tol)
        if self._tp_ctx is not None:
            # shard_map entry point (serving/parallel.py): same impl, same
            # operand positions, traced against the per-device local model
            fused = PL.tp_wrap_fused(self._tp_ctx, self.kernels, impl)
        else:
            fused = functools.partial(impl, self.model, self.kernels)
        self._fused = jax.jit(
            fused, static_argnames=("all_greedy",),
            donate_argnums=() if cpu else (6, 7))       # cache, seq_lens
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl, self.model, self.kernels),
            donate_argnums=() if cpu else (3,))         # slot sub-cache
        self._read_slot = jax.jit(self._read_slot_impl)
        self._write_slot = jax.jit(self._write_slot_impl,
                                   donate_argnums=() if cpu else (0,))

        # ---- prefix-cache persistence (DESIGN.md §16) ----
        if config.prefix_cache_path is not None:
            if self.layout != "paged":
                raise ValueError(
                    "prefix_cache_path persists the hashed prefix cache — "
                    "a paged-layout feature (cache='paged')")
            self._load_prefix_cache(config.prefix_cache_path)

    # ------------------------------------------------------------ jitted fns
    @staticmethod
    def _fused_step_impl(model, kernels, params, tokens, chunk_lens, drafts,
                         draft_lens, emit, cache, seq_lens, block_tables,
                         live, greedy, temps, top_ks, top_ps, keys,
                         draft_probs, *, all_greedy: bool = False,
                         greedy_tol: float | None = None):
        """THE engine program (ISSUE 10, DESIGN.md §18): one forward over a
        token-budgeted batch of per-row chunks, then accept/sample.

        Every row is a ``(chunk_start=seq_lens[i], chunk_len=chunk_lens[i])``
        span of its sequence, right-padded to the bucketed step width C:

        * plain decode       — 1-token chunk, ``draft_lens=0``, ``emit``
        * chunked prefill    — budget-sized chunk; ``emit`` only on the
          chunk that completes the prompt (its last-position logits yield
          the first generated token)
        * speculative verify — (draft_lens+1)-token chunk ``[anchor |
          drafts]``; drafts are spliced in on device so device-resident
          draft-model proposals never round-trip through the host
        * unscheduled rows   — ``live=False``: writes masked, seq_lens kept

        ``accept_speculative`` degenerates to plain greedy/sampled decode at
        ``draft_lens=0`` (window width 1 → bonus token only), so ONE program
        serves every mix.  Cache writes cover ``chunk_lens`` positions
        (write_lens masking: null page on the paged layout, dropped on the
        slot layout); rejected-draft KV is dead weight the next chunk
        overwrites before anything can attend it (rollback by not advancing
        seq_lens).  Returns the packed (B, K+2) int32 transfer
        ``[n_emit | emitted...]``, the cache, and advanced seq_lens.
        """
        b, c = tokens.shape
        k = drafts.shape[1]
        if k and c > k:
            # splice drafts behind each row's anchor token (positions 1..k);
            # rows without drafts (prefill chunks, plain decode) keep their
            # staged tokens
            dmask = jnp.arange(k, dtype=jnp.int32)[None, :] \
                < draft_lens[:, None]
            span = jax.lax.dynamic_slice_in_dim(tokens, 1, k, axis=1)
            tokens = jax.lax.dynamic_update_slice(
                tokens, jnp.where(dmask, drafts, span), (0, 1))
        wl = jnp.where(live, chunk_lens, 0)
        logits, cache = model.forward_chunks(
            params, tokens, wl, cache, seq_lens, kernels=kernels,
            block_tables=block_tables)
        # verify window: positions [start, start+k] score the k drafts + the
        # bonus.  start = chunk_lens-1 for draft-free rows (the last real
        # position — its argmax/sample is the next token), 0 for verify rows
        start = jnp.clip(chunk_lens - 1 - draft_lens, 0, None)
        idx = jnp.clip(
            start[:, None] + jnp.arange(k + 1, dtype=jnp.int32)[None, :],
            0, c - 1)
        window = jnp.take_along_axis(logits, idx[:, :, None], axis=1)
        n_acc, emitted = accept_speculative(
            window, drafts, draft_lens, keys, greedy=greedy, temps=temps,
            top_ks=top_ks, top_ps=top_ps, draft_probs=draft_probs,
            all_greedy=all_greedy, greedy_tol=greedy_tol)
        n_acc = jnp.where(live & (draft_lens > 0), n_acc, 0)
        emit_live = emit & live
        n_emit = jnp.where(emit_live, n_acc + 1, 0)
        emitted = jnp.where(emit_live[:, None], emitted, 0)
        # advance by the accepted span (verify) or the whole chunk; the
        # emitted bonus token is never cache-written — it is the next step's
        # decode input (dead rows: wl=0 and draft_lens=0 keep seq_lens)
        adv = jnp.where(draft_lens > 0, n_acc + 1, wl)
        seq_lens = seq_lens + jnp.where(live, adv, 0)
        packed = jnp.concatenate([n_emit[:, None], emitted],
                                 axis=1).astype(jnp.int32)
        return packed, cache, seq_lens

    @staticmethod
    def _prefill_impl(model, kernels, params, tokens, length, cache, seq_lens):
        # tokens right-padded to a bucket; `length` is the true prompt length.
        # Legacy inline-prefill path: slot-layout families whose caches the
        # write-masked chunked path cannot serve (SSM/SWA/hybrid/meta).
        lengths = jnp.full((tokens.shape[0],), length, jnp.int32)
        logits, cache, seq_lens = model.prefill(
            params, {"tokens": tokens}, cache, seq_lens, kernels=kernels,
            true_lengths=lengths)   # index within the text block
        return logits, cache, seq_lens - (tokens.shape[1] - length)

    @staticmethod
    def _read_slot_impl(cache, slot):
        """Slice one slot's cache sub-tree (batch axis 1; traced slot index,
        so every slot shares a single compiled program)."""
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=1)
            if x.ndim >= 2 else x, cache)

    @staticmethod
    def _write_slot_impl(cache, sub, slot):
        """Write a prefilled sub-tree back into the slot via
        ``dynamic_update_slice`` — no whole-cache-tree rebuild per admit."""
        return jax.tree_util.tree_map(
            lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), slot, axis=1)
            if full.ndim >= 2 else s, cache, sub)

    # -------------------------------------------------------------- lifecycle
    def submit(self, tokens: list[int], max_new_tokens: int = 32,
               sampling: SamplingParams = SamplingParams(greedy=True), *,
               stop_token_ids: Sequence[int] = (),
               ignore_eos: bool = False, priority: int = 0,
               queue_timeout_s: Optional[float] = None) -> int:
        """Queue one request; returns its rid.

        Validates everything a bad request could break later — prompt+decode
        capacity on *both* cache layouts and the ``SamplingParams`` domains —
        so failures surface here with a clear message instead of inside the
        jitted decode step.  ``stop_token_ids`` stop generation like eos
        does; ``ignore_eos=True`` disables the eos stop (fixed-length
        benchmark decoding).

        Overload behaviour (DESIGN.md §14): ``priority`` picks the admission
        class (higher admitted first; on the paged layout a class may
        preempt strictly lower ones under page pressure).  Raises
        ``QueueFullError`` when ``EngineConfig.max_queued`` requests are
        already waiting.  ``queue_timeout_s`` (default
        ``EngineConfig.default_queue_timeout_s``) sheds the request with
        ``FinishReason.SHED`` if it is still unadmitted that many seconds
        after submit.
        """
        tokens = list(tokens)
        if not tokens:
            raise ValueError("empty prompt")
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be > 0, got {max_new_tokens}")
        if queue_timeout_s is not None and queue_timeout_s <= 0:
            raise ValueError(
                f"queue_timeout_s must be > 0, got {queue_timeout_s}")
        sampling.validate(self.model.cfg.vocab_size)
        mq = self.config.max_queued
        if mq is not None and len(self.sched.waiting) >= mq:
            self.metrics.rejected_submits.inc()
            # crude Retry-After: one in-flight generation's worth of steps
            per_step = (self.stats.wall_s / self.stats.steps
                        if self.stats.steps else 0.1)
            raise QueueFullError(
                f"wait queue is full ({mq} requests queued); retry later",
                retry_after_s=max(1.0, per_step * max_new_tokens))
        if self.layout == "paged":
            need = self.pc.pages_needed(len(tokens) + max_new_tokens)
            if need > min(self.pc.max_pages, self.pc.num_pages):
                raise ValueError(
                    f"request needs {need} pages "
                    f"(prompt {len(tokens)} + max_new {max_new_tokens} "
                    f"tokens) but the pool can never provide more than "
                    f"{min(self.pc.max_pages, self.pc.num_pages)}")
        elif len(tokens) + max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {len(tokens) + max_new_tokens} cache "
                f"positions (prompt {len(tokens)} + max_new "
                f"{max_new_tokens} tokens) but slot capacity max_len is "
                f"{self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock.now()
        timeout = (queue_timeout_s if queue_timeout_s is not None
                   else self.config.default_queue_timeout_s)
        req = Request(rid=rid, tokens=tokens,
                      max_new_tokens=max_new_tokens, sampling=sampling,
                      arrival=now,
                      stop_token_ids=tuple(stop_token_ids),
                      ignore_eos=ignore_eos, priority=priority,
                      queue_deadline=(now + timeout
                                      if timeout is not None else None))
        self._requests[rid] = req
        self.sched.submit(req)
        if self.tracer is not None:
            self.tracer.request_state(rid, "QUEUED", now,
                                      prompt_len=len(tokens),
                                      max_new_tokens=max_new_tokens,
                                      priority=priority)
        return rid

    def state_of(self, rid: int) -> RequestState:
        """Current lifecycle state of a submitted request."""
        return self._requests[rid].state

    def abort(self, rid: int) -> Optional[RequestOutput]:
        """Cancel a queued or in-flight request.

        Frees its decode slot or paged reservation immediately (page
        refcounts — including prefix-cache-shared pages — return to their
        pre-request values; the block-table row and free list are restored).
        Returns the partial ``RequestOutput`` with
        ``finish_reason=FinishReason.ABORT``, or None when the rid is
        unknown or already finished.  A terminal ``StreamEvent`` is emitted
        so ``stream()`` consumers observe the abort.
        """
        req = self.sched.cancel(rid)
        if req is not None:     # queued (or preempted): no device resources
            if self.layout == "paged":
                self.pc.drop_offloaded(rid)   # free any host checkpoint
            req.state = RequestState.ABORTED
            out = RequestOutput(
                rid=rid, prompt_len=len(req.tokens),
                output=list(req.saved_output),
                arrival=req.arrival, t_first_token=req.saved_t_first,
                t_done=self.clock.now(), finish_reason=FinishReason.ABORT)
            self.metrics.requests_finished.labels(reason="abort").inc()
            if self.tracer is not None:
                self.tracer.request_end(rid, "abort", out.t_done,
                                        tokens=len(out.output))
            self._events.append(StreamEvent(
                rid=rid, token=None, index=len(out.output),
                finish_reason=FinishReason.ABORT, output=out))
            return out
        hit = self.sched.find_active(rid)
        if hit is None:
            return None
        row, a = hit
        out = self._finish(row, [], FinishReason.ABORT)
        self._events.append(StreamEvent(
            rid=rid, token=None, index=len(out.output),
            finish_reason=FinishReason.ABORT, output=out))
        return out

    def _stop_reason(self, a: Active) -> Optional[FinishReason]:
        """Per-request stop criteria, checked after each generated token."""
        tok, req = a.output[-1], a.req
        if tok in req.stop_token_ids:
            return FinishReason.STOP
        if not req.ignore_eos and tok == self.eos_id:
            return FinishReason.STOP
        if len(a.output) >= req.max_new_tokens:
            return FinishReason.LENGTH
        return None

    def _emit_token(self, a: Active, row: int, tok: int,
                    finished: list[RequestOutput]):
        """Record one generated token: stop-criteria check, terminal
        bookkeeping, and the StreamEvent for ``stream()`` consumers."""
        reason = self._stop_reason(a)
        out = self._finish(row, finished, reason) if reason else None
        self._events.append(StreamEvent(
            rid=a.req.rid, token=tok, index=len(a.output) - 1,
            finish_reason=reason, output=out))

    def _sample_first(self, logits, req: Request) -> int:
        """Sample the first generated token from the prefill logits."""
        self.rng, k = jax.random.split(self.rng)
        return int(sample(logits, k, req.sampling)[0])

    def _shed_expired(self, finished: list[RequestOutput]):
        """Graceful shedding (DESIGN.md §14): drop queued requests whose
        queue deadline passed before admission.  They hold no resources;
        clients observe ``FinishReason.SHED`` (HTTP 503 + Retry-After)."""
        now = self.clock.now()
        for req in self.sched.pop_expired(now):
            req.state = RequestState.FINISHED
            out = RequestOutput(
                rid=req.rid, prompt_len=len(req.tokens), output=[],
                arrival=req.arrival, t_first_token=0.0, t_done=now,
                finish_reason=FinishReason.SHED)
            self.metrics.shed_requests.inc()
            self.metrics.requests_finished.labels(reason="shed").inc()
            if self.tracer is not None:
                self.tracer.request_end(req.rid, "shed", now,
                                        queued_s=now - req.arrival)
            finished.append(out)
            self._events.append(StreamEvent(
                rid=req.rid, token=None, index=0,
                finish_reason=FinishReason.SHED, output=out))

    def _admit(self, finished: list[RequestOutput]):
        self._shed_expired(finished)
        if self.layout == "paged":
            self._admit_paged(finished)
        else:
            self._admit_slot(finished)

    def _admit_slot(self, finished: list[RequestOutput]):
        for req in self.sched.admit(self.slots.num_free):
            slot = self.slots.alloc()
            assert slot is not None
            a = self.sched.activate(req, slot)
            a.t_admit = self.clock.now()
            self.metrics.queue_wait.observe(a.t_admit - req.arrival)
            if not self._chunked:
                self._prefill_slot_inline(req, a, slot, finished)
                continue
            # reservation only: the prompt streams into the slot as fused-
            # step chunks (write_lens drops each chunk's padded positions)
            a.prefill_ctx = req.tokens
            a.prefill_pos = 0
            a.prefill_end = len(req.tokens)
            self.slots.seq_lens = self.slots.seq_lens.at[slot].set(0)
            if self.tracer is not None:
                self.tracer.request_state(req.rid, "PREFILL", a.t_admit,
                                          prompt_len=len(req.tokens),
                                          slot=slot)

    def _prefill_slot_inline(self, req: Request, a: Active, slot: int,
                             finished: list[RequestOutput]):
        """Legacy whole-prompt prefill at admission, for slot-layout
        families the write-masked chunked path cannot serve: recurrent
        state (SSM) and ring caches (SWA) are polluted by padded tokens ->
        exact-length prefill for those families (one compile per length)."""
        t_admit = a.t_admit
        cfg = self.model.cfg
        paddable = cfg.family not in ("ssm", "hybrid") \
            and not cfg.sliding_window
        blen = bucket_len(len(req.tokens)) if paddable else len(req.tokens)
        if self.tracer is not None:
            self.tracer.request_state(req.rid, "PREFILL", t_admit,
                                      prompt_len=len(req.tokens),
                                      prefill_chunk=blen, slot=slot)
        toks = np.zeros((1, blen), np.int32)
        toks[0, :len(req.tokens)] = req.tokens
        slot_idx = jnp.asarray(slot, jnp.int32)
        sub_cache = self._read_slot(self.slots.cache, slot_idx)
        sub_lens = jnp.zeros((1,), jnp.int32)
        logits, sub_cache, sub_lens = self._prefill(
            self.params, jnp.asarray(toks), len(req.tokens), sub_cache,
            sub_lens)
        self.slots.cache = self._write_slot(self.slots.cache, sub_cache,
                                            slot_idx)
        self.slots.seq_lens = self.slots.seq_lens.at[slot].set(sub_lens[0])
        self.metrics.prefill_tokens.inc(len(req.tokens))
        tok = self._sample_first(logits, req)
        a.t_first_token = self.clock.now()
        self.metrics.ttft.labels(priority=req.priority).observe(
            a.t_first_token - req.arrival)
        a.output.append(tok)
        req.state = RequestState.RUNNING
        if self.tracer is not None:
            self.tracer.prefill_span(req.rid, t_admit, a.t_first_token,
                                     prefill_chunk=blen,
                                     prefill_tokens=len(req.tokens))
            self.tracer.request_state(req.rid, "RUNNING", a.t_first_token)
        self._emit_token(a, slot, tok, finished)

    # --------------------------------------------- paged admission/preemption
    def _gather_pages(self, page_ids: list[int]):
        """Host copies of the named physical pages from the engine's model
        cache tree (page axis 1 in every pool/scale leaf) — the payload
        mover ``PagedCache.offload`` uses under ``alloc_pools=False``."""
        idx = np.asarray(page_ids, np.int32)
        return jax.tree_util.tree_map(lambda a: np.asarray(a[:, idx]),
                                      self.cache)

    def _scatter_pages(self, page_ids: list[int], payload):
        """Write host pages back into the model cache tree at freshly
        allocated physical page ids (restore counterpart)."""
        idx = jnp.asarray(page_ids, jnp.int32)
        self.cache = jax.tree_util.tree_map(
            lambda a, h: a.at[:, idx].set(jnp.asarray(h, a.dtype)),
            self.cache, payload)

    # ----------------------------------- prefix-cache persistence (§16)
    def save_prefix_cache(self, path: Optional[str] = None) -> int:
        """Serialize the hashed prefix-cache index + its page payloads to a
        directory (``index.json`` + ``pages.npz``) so a future engine with
        the same model/quant config starts warm.  Safe because the hash
        chain is deterministic across processes (sha256 seed keyed by the
        kv-quant mode + page size — ``kv_cache.prefix_hash_seed``), so the
        persisted keys mean the same token prefixes to the loader.
        Returns the number of pages written."""
        if self.layout != "paged":
            raise ValueError("prefix-cache persistence is paged-layout only")
        path = path if path is not None else self.config.prefix_cache_path
        if path is None:
            raise ValueError("no prefix_cache_path configured or passed")
        pc = self.pc
        keys, pages = pc.export_prefix_index()
        payload = self._gather_pages(pages) if pages else None
        leaves = jax.tree_util.tree_leaves(payload) if pages else []
        os.makedirs(path, exist_ok=True)
        index = {"version": 1, "seed": int(pc._hash_seed),
                 "page_size": pc.page_size,
                 "keys": [str(k) for k in keys], "n_leaves": len(leaves)}
        if leaves:
            np.savez(os.path.join(path, "pages.npz"),
                     **{f"leaf_{i}": leaf for i, leaf in enumerate(leaves)})
        with open(os.path.join(path, "index.json"), "w") as f:
            json.dump(index, f)
        return len(pages)

    def _load_prefix_cache(self, path: str) -> int:
        """Warm-start the prefix cache from ``save_prefix_cache`` output.
        Missing directory/index is a cold start (returns 0); an index saved
        under a different quant mode or page size raises — its page bytes
        would be silently wrong for this cache.  Adopted pages are pinned
        (refcount 1, no owning sequence) so the warm set is never evicted;
        pool pressure permitting, a prefix subset is adopted."""
        pc = self.pc
        index_path = os.path.join(path, "index.json")
        if not os.path.exists(index_path):
            return 0
        with open(index_path) as f:
            index = json.load(f)
        if (index.get("seed") != int(pc._hash_seed)
                or index.get("page_size") != pc.page_size):
            raise ValueError(
                f"prefix cache at {path!r} was saved under a different "
                f"kv-quant mode or page size (seed/page_size mismatch) — "
                f"its page payloads are not valid for this engine")
        keys = [int(k) for k in index["keys"]]
        if not keys:
            return 0
        if index["n_leaves"] != len(jax.tree_util.tree_leaves(self.cache)):
            raise ValueError(
                f"prefix cache at {path!r} was saved from a different model "
                f"cache shape ({index['n_leaves']} leaves)")
        data = np.load(os.path.join(path, "pages.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(index["n_leaves"])]
        treedef = jax.tree_util.tree_structure(self.cache)
        payload = jax.tree_util.tree_unflatten(treedef, leaves)
        adopted = pc.adopt_prefix_pages(keys)
        if not adopted:
            return 0
        col = {k: i for i, k in enumerate(keys)}
        cols = [col[k] for k, _ in adopted]
        dest = [p for _, p in adopted]
        sub = jax.tree_util.tree_map(lambda a: a[:, cols], payload)
        self._scatter_pages(dest, sub)
        return len(adopted)

    def _ctx_tokens(self, req: Request) -> list[int]:
        """The token span a preempted request's KV checkpoint covers:
        prompt plus every generated token already *written* to the cache —
        the last sampled token is the next decode input, not yet written."""
        return req.tokens + req.saved_output[:-1]

    def _preempt_victim(self, min_priority: int) -> bool:
        """Evict the best victim below ``min_priority``: retire it from the
        batch, checkpoint its private pages to host memory, release its
        reservation, and re-queue it (PREEMPTED, original queue order, its
        generated tokens saved for the restore)."""
        row = self.sched.preemption_victim(min_priority)
        if row is None:
            return False
        if self._spec is not None:
            self._spec.invalidate(row)
        a = self.sched.retire(row)
        req = a.req
        rec = self.pc.offload(req.rid, gather=self._gather_pages)
        req.saved_output = a.output
        req.saved_t_first = a.t_first_token
        req.state = RequestState.PREEMPTED
        self.sched.requeue(req)
        m = self.metrics
        m.preemptions.inc()
        m.offloaded_pages.inc(rec.n_payload_pages)
        m.offloaded_bytes.inc(rec.nbytes)
        if self.tracer is not None:
            now = self.clock.now()
            self.tracer.request_instant(
                req.rid, "offload", now, pages=rec.n_payload_pages,
                shared_pages=rec.shared_pages, bytes=rec.nbytes)
            self.tracer.request_state(req.rid, "PREEMPTED", now,
                                      offloaded_pages=rec.n_payload_pages)
        return True

    def _try_reserve(self, req: Request) -> bool:
        """One reservation attempt: restore an offloaded victim, or a fresh
        prompt+decode footprint reservation with prefix registration."""
        if req.rid in self.pc.offloaded:
            info = self.pc.restore(
                req.rid, self._ctx_tokens(req),
                reserve=req.max_new_tokens - len(req.saved_output) + 1,
                scatter=self._scatter_pages)
            if info is None:
                return False
            self._pending_restores[req.rid] = info
            return True
        if not self.pc.alloc_seq(req.rid, len(req.tokens), tokens=req.tokens,
                                 reserve=req.max_new_tokens):
            return False
        # prefix registration is deferred to prompt completion (``step``):
        # the prompt KV now streams in over several fused-step chunks, so
        # registering here would let a follower share still-unwritten pages
        return True

    def _prefix_pending(self, req: Request) -> bool:
        """True when an active mid-prefill row's context shares at least one
        full page with ``req``'s prompt: that leader will publish those pages
        to the prefix cache once its last chunk lands, so admitting ``req``
        now would forfeit the share (the registry only lists written pages).
        Deferring one round costs at most the leader's remaining prefill."""
        ps = self.pc.page_size
        ctxs = [a.prefill_ctx for a in self.sched.active.values()
                if a.pending_prefill]
        # leaders reserved earlier in this same admission round are not in
        # ``active`` yet (activation happens once the round closes)
        ctxs += [r.tokens for r in self._admit_round]
        for ctx in ctxs:
            n = min(len(req.tokens), len(ctx)) // ps * ps
            lcp = next((i for i in range(n) if req.tokens[i] != ctx[i]), n)
            if lcp >= ps:
                return True
        return False

    def _reserve_paged(self, req: Request) -> bool:
        """Admission policy for ``Scheduler.admit``: reserve the request's
        whole prompt+decode page footprint (minus prefix-cache hits) and a
        block-table row, or defer.  The request's prompt pages enter the
        prefix cache only once its last prefill chunk has written them
        (``step``) — a follower can never share still-unwritten pages;
        instead its admission waits until the leader's prefix is published.

        When the reservation fails and preemption is enabled, victims
        strictly below this request's priority are evicted (lowest class
        first, most-recently-admitted within it) until the reservation fits
        or no eligible victim remains (DESIGN.md §14)."""
        if req.rid not in self.pc.offloaded and self._prefix_pending(req):
            self.metrics.deferred_admissions.inc()
            return False
        ok = self._try_reserve(req)
        while (not ok and self.config.preemption
               and self._preempt_victim(req.priority)):
            ok = self._try_reserve(req)
        if not ok:
            self.metrics.deferred_admissions.inc()
        elif req.rid not in self._pending_restores:
            self._admit_round.append(req)
        return ok

    def _resume_restored(self, req: Request, a: Active, row: int,
                         info: KV.RestoredSeq):
        """Re-activate a preempted request after its pages came back
        on-device: re-attach its generated tokens and schedule any prefix
        span whose donor evicted while it was offloaded (``[hit_pages,
        snap_start_page)`` — restore left those pages empty) as fused-step
        chunks.  No token is sampled for the gap (``prefill_sample=False``):
        the next token comes from the next decode step, fed the last
        generated token — which makes the round trip token-identical under
        greedy."""
        pc = self.pc
        ctx = self._ctx_tokens(req)
        a.output = req.saved_output
        a.t_first_token = req.saved_t_first
        req.saved_output = []
        gap_start = info.hit_pages * pc.page_size
        gap_end = info.snap_start_page * pc.page_size
        m = self.metrics
        m.restored_pages.inc(info.restored_pages)
        m.prefix_hit_pages.inc(info.hit_pages)
        m.prefix_hit_tokens.inc(gap_start)
        if self.tracer is not None:
            now = self.clock.now()
            self.tracer.request_instant(
                req.rid, "restore", now, restored_pages=info.restored_pages,
                hit_pages=info.hit_pages,
                gap_recompute_tokens=max(0, gap_end - gap_start))
        if gap_start < gap_end:
            # stream the donor-evicted span back through budget-sized
            # chunks; the row decodes again once they land (its snapshot
            # pages past the gap already hold KV — ``resume_len`` is
            # published then)
            a.prefill_ctx = ctx
            a.prefill_pos = gap_start
            a.prefill_end = gap_end
            a.prefill_sample = False
            a.resume_len = info.length
            pc.seq_lens = pc.seq_lens.at[row].set(gap_start)
            return
        pc.seq_lens = pc.seq_lens.at[row].set(info.length)
        pc.register_prefix(req.rid, ctx)
        req.state = RequestState.RUNNING
        if self.tracer is not None:
            self.tracer.request_state(req.rid, "RUNNING", self.clock.now(),
                                      restored=True)

    def _admit_paged(self, finished: list[RequestOutput]):
        pc = self.pc
        self._admit_round = []
        for req in self.sched.admit(self._reserve_paged):
            row = pc.row_of(req.rid)
            a = self.sched.activate(req, row)
            a.t_admit = self.clock.now()
            self.metrics.queue_wait.observe(a.t_admit - req.arrival)
            info = self._pending_restores.pop(req.rid, None)
            if info is not None:
                # preemption restore: pages are back (host scatter already
                # done by _try_reserve); no first-token sample — decode
                # continues where it left off, possibly after gap chunks
                self._resume_restored(req, a, row, info)
                continue
            hit_pages = pc.prefix_hits.get(req.rid, 0)
            if hit_pages * pc.page_size >= len(req.tokens):
                # Full-prefix hit (ISSUE 5): a zero-token suffix chunk would
                # leave no position to sample the first token from.  Back
                # off so at least the last prompt token is recomputed; the
                # backed-off pages are swapped private first so a donor's
                # live pages are never rewritten.  Unreachable via
                # ``alloc_seq``'s own hit cap — this guards any future
                # admission path that shares more aggressively.
                hit_pages = (len(req.tokens) - 1) // pc.page_size
                pc.release_prefix(req.rid, hit_pages)
                pc.prefix_hits[req.rid] = hit_pages
            hit_tokens = hit_pages * pc.page_size
            # reservation only: the prompt suffix streams into the reserved
            # pages as fused-step chunks (Scheduler.plan_chunks deals them
            # under the token budget); the device row starts at the hit
            a.prefill_ctx = req.tokens
            a.prefill_pos = hit_tokens
            a.prefill_end = len(req.tokens)
            pc.seq_lens = pc.seq_lens.at[row].set(hit_tokens)
            pc.lengths[req.rid] = hit_tokens
            m = self.metrics
            m.prefix_hit_pages.inc(hit_pages)
            m.prefix_hit_tokens.inc(hit_tokens)
            if self.tracer is not None:
                self.tracer.request_state(
                    req.rid, "PREFILL", a.t_admit,
                    prompt_len=len(req.tokens), prefix_hit_pages=hit_pages,
                    pages_reserved=len(pc.tables[req.rid]), row=row)

    def _finish(self, row: int, finished: list[RequestOutput],
                reason: FinishReason = FinishReason.STOP) -> RequestOutput:
        a = self.sched.retire(row)
        if self.layout == "paged":
            self.pc.free_seq(a.req.rid)
        else:
            self.slots.free(row)
        a.req.state = (RequestState.ABORTED if reason is FinishReason.ABORT
                       else RequestState.FINISHED)
        if self._spec is not None:
            self._spec.invalidate(row)
        out = RequestOutput(
            rid=a.req.rid, prompt_len=len(a.req.tokens), output=a.output,
            arrival=a.req.arrival, t_first_token=a.t_first_token,
            t_done=self.clock.now(), finish_reason=reason,
            spec_proposed=a.req.spec_proposed,
            spec_accepted=a.req.spec_accepted)
        m = self.metrics
        m.requests_finished.labels(reason=reason.value).inc()
        if out.t_first_token:
            m.request_latency.observe(out.latency)
        if out.tpot > 0.0:
            m.tpot.observe(out.tpot)
        if self.tracer is not None:
            self.tracer.request_end(out.rid, reason.value, out.t_done,
                                    tokens=len(out.output))
        finished.append(out)
        return out

    # a legacy `while True: eng.step()` loop never drains the event buffer;
    # cap it (drop-oldest) so such callers don't grow memory unboundedly
    _MAX_PENDING_EVENTS = 65_536

    def _step_width(self, need: int) -> int:
        """Bucketed fused-step width: smallest of ``_width_buckets`` (1,
        k+1, then the prefill buckets) holding ``need`` tokens; multiples
        of 4096 past the table.  Bounds jit recompiles under mixed
        traffic."""
        for b in self._width_buckets:
            if need <= b:
                return b
        return -(-need // 4096) * 4096

    def step(self) -> list[RequestOutput]:
        """One engine iteration: admissions + ONE fused-step invocation
        (DESIGN.md §18) covering every live row's chunk — decode, chunked
        prefill and spec-verify together.

        Wall-clock accounting happens *here* (one clock read at entry, one
        at exit) so every pump — ``run``/``generate``/``stream`` wrappers,
        the HTTP worker thread, or a bare ``while: eng.step()`` loop —
        accounts identically into ``engine_wall_seconds_total``.

        Rollback is implicit under speculation: ``seq_lens`` (and the host
        page-length mirror) advance only to the accepted position;
        rejected positions' KV is dead weight that the next chunk
        overwrites before anything can attend it.  Per-row draft budgets
        are capped at ``max_new - emitted - 1`` so a full acceptance plus
        the bonus token lands exactly on the reserved page/slot footprint,
        never past it.
        """
        t_step0 = self.clock.now()
        if self.faults is not None:
            # deterministic fault injection (serving/faults.py): scheduled
            # page seizures, stalls and aborts fire before admissions
            self.faults.on_step(self)
        if len(self._events) > self._MAX_PENDING_EVENTS:
            del self._events[:len(self._events) - self._MAX_PENDING_EVENTS]
        finished: list[RequestOutput] = []
        self._admit(finished)
        self.metrics.peak_active.set_max(len(self.sched.active))
        if not self.sched.active:
            self._end_step(t_step0, finished, decoded=0)
            return finished
        bs = self.batch_rows
        spec = self._spec
        k = spec.k if spec is not None else 0
        # token-budget packing: every decode row claims its reserve (1
        # plain, k+1 under speculation), the remaining budget is dealt to
        # mid-prefill rows as prompt chunks; budget-starved prefill rows
        # sit this step out (live=False, writes masked, seq_lens kept)
        plan = self.sched.plan_chunks(self.config.max_step_tokens,
                                      reserve=k + 1)
        decode_rows = {row: a for row, a in self.sched.active.items()
                       if not a.pending_prefill}
        # host-side staging: per-row sampling arrays + chunk spans (numpy,
        # no device round-trips)
        live = np.zeros((bs,), np.bool_)
        emit = np.zeros((bs,), np.bool_)
        chunk_lens = np.zeros((bs,), np.int32)
        greedy = np.ones((bs,), np.bool_)
        temps = np.ones((bs,), np.float32)
        top_ks = np.zeros((bs,), np.int32)
        top_ps = np.ones((bs,), np.float32)
        for row, a in self.sched.active.items():
            sp = a.req.sampling
            greedy[row] = sp.greedy or sp.temperature == 0.0
            temps[row] = sp.temperature if sp.temperature > 0.0 else 1.0
            top_ks[row] = sp.top_k
            top_ps[row] = sp.top_p
        all_greedy = bool(greedy.all())

        # ---- speculative proposal (decode rows only) ----
        lens = np.zeros((bs,), np.int32)
        drafts_dev = jnp.zeros((bs, k), jnp.int32)
        probs = None
        proposed = 0
        t_p1 = t_step0
        if spec is not None and decode_rows:
            rows: dict[int, tuple[int, list[int], int]] = {}
            caps = np.zeros((bs,), np.int32)
            for row, a in decode_rows.items():
                cap = max(0, min(spec.k,
                                 a.req.max_new_tokens - len(a.output) - 1))
                rows[row] = (a.req.rid, a.req.tokens + a.output, cap)
                caps[row] = cap
            t_p0 = self.clock.now()
            samp_host = None if all_greedy \
                else (greedy, temps, top_ks, top_ps)
            prop = spec.propose(rows, all_greedy=all_greedy, samp=samp_host)
            lens = np.minimum(np.asarray(prop.draft_lens, np.int32), caps)
            drafts_dev = prop.drafts \
                if not isinstance(prop.drafts, np.ndarray) \
                else jnp.asarray(prop.drafts)
            probs = prop.probs
            proposed = int(lens.sum())
            t_p1 = self.clock.now()
            self.metrics.spec_proposed.inc(proposed)
            for row, a in decode_rows.items():
                a.req.spec_proposed += int(lens[row])
            if self.tracer is not None:
                self.tracer.propose_span(t_p0, t_p1, step=self._step_no,
                                         proposed=proposed,
                                         batch=len(decode_rows))

        # ---- chunk staging: decode anchors (+drafts on device), prompt
        # chunks from the plan ----
        need = k + 1 if (spec is not None and decode_rows) else 1
        if plan:
            need = max(need, max(plan.values()))
        width = self._step_width(need)
        tokens = np.zeros((bs, width), np.int32)
        for row, a in decode_rows.items():
            live[row] = True
            emit[row] = True
            chunk_lens[row] = int(lens[row]) + 1
            tokens[row, 0] = a.output[-1] if a.output else a.req.tokens[-1]
        for row, c in plan.items():
            a = self.sched.active[row]
            live[row] = True
            chunk_lens[row] = c
            tokens[row, :c] = \
                a.prefill_ctx[a.prefill_pos:a.prefill_pos + c]
            # the chunk that completes the prompt emits the first token
            # (restore-gap chunks never sample — the next token is already
            # in the request's output)
            emit[row] = (a.prefill_pos + c >= a.prefill_end
                         and a.prefill_sample)
        if all_greedy:
            # argmax-only trace: no rng consumption, no sampling operands
            samp = (None, None, None, None, None)
        else:
            self.rng, sub = jax.random.split(self.rng)
            samp = (jnp.asarray(greedy), jnp.asarray(temps),
                    jnp.asarray(top_ks), jnp.asarray(top_ps),
                    jax.random.split(sub, bs))
        head = (self.params, jnp.asarray(tokens), jnp.asarray(chunk_lens),
                drafts_dev, jnp.asarray(lens), jnp.asarray(emit))
        if self.layout == "paged":
            pc = self.pc
            packed_dev, self.cache, pc.seq_lens = self._fused(
                *head, self.cache, pc.seq_lens, pc.block_tables,
                jnp.asarray(live), *samp, probs, all_greedy=all_greedy)
        else:
            packed_dev, self.slots.cache, self.slots.seq_lens = self._fused(
                *head, self.slots.cache, self.slots.seq_lens, None,
                jnp.asarray(live), *samp, probs, all_greedy=all_greedy)
        # the single device->host transfer of the step
        packed = np.asarray(jax.device_get(packed_dev))
        decoded = 0
        accepted_total = 0
        for row in sorted(self.sched.active):
            a = self.sched.active[row]
            rid = a.req.rid
            if not live[row]:
                continue
            if row in plan:
                self._advance_prefill(a, row, plan[row], packed, finished)
                continue
            n_emit = int(packed[row, 0])
            n_acc = n_emit - 1
            if self.layout == "paged":
                self.pc.lengths[rid] += n_emit   # host seq_lens mirror
            if spec is not None:
                a.req.spec_accepted += n_acc
                accepted_total += n_acc
                self.metrics.spec_accepted.inc(n_acc)
                self.metrics.spec_accept_len.observe(n_acc)
            for tok in packed[row, 1:1 + n_emit].tolist():
                decoded += 1
                a.output.append(int(tok))
                self._emit_token(a, row, int(tok), finished)
                if row not in self.sched.active:
                    # retired mid-span (stop token / length / abort): the
                    # retirement already freed the row's device state, so
                    # later emitted tokens are dropped with it
                    break
            else:
                if spec is not None:
                    spec.observe(row, rid, n_acc)
        m = self.metrics
        m.tokens_generated.inc(decoded)
        m.steps.inc()
        if spec is not None and decode_rows:
            m.spec_verify_steps.inc()
            if self.tracer is not None:
                self.tracer.verify_span(t_p1, self.clock.now(),
                                        step=self._step_no,
                                        proposed=proposed,
                                        accepted=accepted_total,
                                        decoded=decoded)
        self._end_step(t_step0, finished, decoded=decoded)
        return finished

    def _advance_prefill(self, a: Active, row: int, c: int, packed,
                         finished: list[RequestOutput]) -> None:
        """Bookkeeping for one landed prefill chunk: advance the span; on
        the chunk that completes the prompt, register the now-written
        prefix pages and surface the first generated token (or, for a
        restore gap, publish the resumed length — its next token is
        already in the request's output)."""
        req = a.req
        a.prefill_pos += c
        self.metrics.prefill_tokens.inc(c)
        pc = self.pc if self.layout == "paged" else None
        if pc is not None and a.prefill_sample:
            pc.lengths[req.rid] += c   # host seq_lens mirror
        if a.pending_prefill:
            return
        if not a.prefill_sample:
            # restore gap recomputed: the snapshot pages past the gap
            # already hold KV — publish the full resumed length
            if pc is not None:
                pc.seq_lens = pc.seq_lens.at[row].set(a.resume_len)
                pc.register_prefix(req.rid, a.prefill_ctx)
            a.prefill_sample = True
            a.resume_len = 0
            req.state = RequestState.RUNNING
            if self.tracer is not None:
                self.tracer.request_state(req.rid, "RUNNING",
                                          self.clock.now(), restored=True)
            return
        if pc is not None:
            pc.register_prefix(req.rid, a.prefill_ctx)
        tok = int(packed[row, 1])
        a.t_first_token = self.clock.now()
        self.metrics.ttft.labels(priority=req.priority).observe(
            a.t_first_token - req.arrival)
        a.output.append(tok)
        req.state = RequestState.RUNNING
        if self.tracer is not None:
            self.tracer.prefill_span(req.rid, a.t_admit, a.t_first_token,
                                     prefill_chunk=c,
                                     prefill_tokens=a.prefill_end)
            self.tracer.request_state(req.rid, "RUNNING", a.t_first_token)
        self._emit_token(a, row, tok, finished)

    def _end_step(self, t0: float, finished: list[RequestOutput],
                  decoded: int) -> None:
        """Close out one ``step()``: wall/duration accounting, occupancy
        gauges, and the step's trace span.  Host-side bookkeeping only —
        nothing here touches a device value."""
        t1 = self.clock.now()
        m = self.metrics
        m.wall_seconds.inc(t1 - t0)
        m.step_duration.observe(t1 - t0)
        m.active_requests.set(len(self.sched.active))
        m.waiting_requests.set(len(self.sched.waiting))
        if self.layout == "paged":
            m.sync_pool(self.pc)
        if self.tracer is not None:
            args = {"step": self._step_no, "batch": len(self.sched.active),
                    "waiting": len(self.sched.waiting), "decoded": decoded,
                    "finished": len(finished)}
            if self.layout == "paged":
                occ = self.pc.occupancy()
                args["free_pages"] = occ["free_pages"]
                args["pool_utilization"] = round(occ["utilization"], 6)
            self.tracer.step_span(t0, t1, **args)
        self._step_no += 1

    def drain_events(self) -> list[StreamEvent]:
        """Take ownership of the pending ``StreamEvent``s (per-token events
        from ``step()`` and terminal abort events) without stepping."""
        events, self._events = self._events, []
        return events

    def step_events(self) -> list[StreamEvent]:
        """One engine iteration, returning the per-token ``StreamEvent``s it
        produced (plus any pending abort events) instead of just the
        finished requests."""
        self.step()
        return self.drain_events()

    def run(self, *, max_steps: int = 10_000) -> list[RequestOutput]:
        """Drain the queue; returns finished requests with latency stats.

        Wall time is accounted inside ``step()`` — there is no extra
        accounting here, so driving ``step()`` directly reads the same."""
        out: list[RequestOutput] = []
        steps = 0
        while not self.sched.idle and steps < max_steps:
            out.extend(self.step())
            self._events.clear()       # run() consumers read outputs, not events
            steps += 1
        return out

    def generate(self, prompts, *, max_new_tokens: int = 32,
                 sampling: SamplingParams = SamplingParams(greedy=True),
                 stop_token_ids: Sequence[int] = (),
                 ignore_eos: bool = False,
                 max_steps: int = 10_000) -> list[RequestOutput]:
        """Blocking convenience: submit ``prompts`` (one token-id list, or a
        list of them) and pump ``step()`` until they all finish.  Returns
        their ``RequestOutput``s in submission order.  ``sampling`` may be a
        single ``SamplingParams`` or one per prompt."""
        if prompts and isinstance(prompts[0], int):
            prompts = [prompts]
        samplings = (list(sampling) if isinstance(sampling, (list, tuple))
                     else [sampling] * len(prompts))
        if len(samplings) != len(prompts):
            raise ValueError(
                f"{len(prompts)} prompts but {len(samplings)} SamplingParams")
        rids = [self.submit(p, max_new_tokens=max_new_tokens, sampling=sp,
                            stop_token_ids=stop_token_ids,
                            ignore_eos=ignore_eos)
                for p, sp in zip(prompts, samplings)]
        want = set(rids)
        outs: dict[int, RequestOutput] = {}
        steps = 0
        while want and not self.sched.idle and steps < max_steps:
            for out in self.step():
                if out.rid in want:
                    outs[out.rid] = out
                    want.discard(out.rid)
            self._events.clear()
            steps += 1
        return [outs[r] for r in rids if r in outs]

    def stream(self, *, max_steps: int = 10_000) -> Iterator[StreamEvent]:
        """Pump ``step()`` until the engine is idle, yielding one
        ``StreamEvent`` per generated token across all in-flight requests —
        continuous batching preserved (new submissions made while iterating
        are admitted and interleaved).  Terminal events carry the request's
        ``RequestOutput``; aborts surface as terminal events too."""
        steps = 0
        while not self.sched.idle and steps < max_steps:
            yield from self.step_events()
            steps += 1
        # e.g. an abort() that idled the engine mid-iteration
        yield from self.drain_events()
