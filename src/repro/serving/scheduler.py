"""Continuous-batching scheduler: priority-class admission into decode
slots, bucketed prefill lengths (bounded jit recompiles), per-request
lifecycle tracking.

Admission order is (priority desc, submission order asc) — FCFS within a
priority class, strictly higher classes first.  Preempted requests re-enter
the queue via ``requeue`` keeping their original submission order, so a
restored victim goes back to the head of its class (it has progress; letting
it finish frees capacity soonest).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Union

from repro.serving.api import RequestOutput, RequestState
from repro.serving.sampler import SamplingParams

# Backwards-compatible alias: the engine used to return ``Finished`` records;
# the redesigned API calls the same record ``RequestOutput`` (serving/api.py).
Finished = RequestOutput


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    arrival: float = 0.0
    # per-request stop criteria (ISSUE 3): extra stop token ids beyond eos,
    # and an eos opt-out for benchmark-style fixed-length generation
    stop_token_ids: tuple[int, ...] = ()
    ignore_eos: bool = False
    state: RequestState = RequestState.QUEUED
    # ---- overload resilience (ISSUE 6 / DESIGN.md §14) ----
    priority: int = 0                    # higher = admitted (and kept) first
    # absolute clock time after which a still-QUEUED request is shed
    queue_deadline: float | None = None
    # preemption checkpoint: generated tokens + first-token timestamp saved
    # when the request is evicted mid-decode, consumed on restore
    saved_output: list[int] = dataclasses.field(default_factory=list)
    saved_t_first: float = 0.0
    # queue position (assigned once at first submit; stable across requeues)
    order: int | None = None
    # ---- speculative decoding (DESIGN.md §16) ----
    # draft tokens proposed for / accepted by this request across its
    # verify steps (both stay 0 with speculation off); survive preemption
    spec_proposed: int = 0
    spec_accepted: int = 0


@dataclasses.dataclass
class Active:
    req: Request
    slot: int
    output: list[int] = dataclasses.field(default_factory=list)
    t_first_token: float = 0.0
    # monotone admission stamp — preemption picks the most-recently-admitted
    # victim within the lowest priority class (it has the least sunk work)
    admit_seq: int = 0
    # clock time of admission — the prefill span start (the prompt now
    # streams in over several fused steps, so the span closes later)
    t_admit: float = 0.0
    # ---- chunked prefill (ISSUE 10 / DESIGN.md §18) ----
    # admission reserves cache space but writes no prompt KV; the prompt
    # context streams into the cache as budget-sized chunks of the fused
    # step.  ``prefill_ctx[prefill_pos:prefill_end]`` is what remains.
    prefill_ctx: list[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0
    prefill_end: int = 0
    # sample + emit the first token when the last chunk lands (False for a
    # restore's donor-gap re-prefill: its next token is already in output)
    prefill_sample: bool = True
    # restore path: row length to publish once the gap chunks land (the
    # snapshot pages beyond the gap already hold KV); 0 = prefill_end
    resume_len: int = 0

    @property
    def pending_prefill(self) -> bool:
        return self.prefill_pos < self.prefill_end


PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_len(n: int) -> int:
    """Smallest prefill bucket holding ``n`` tokens; multiples of 4096 past
    the bucket table.  ``n <= 0`` is 0 — there is nothing to prefill, and the
    old behaviour (pad 0 up to 32) silently prefilled a block of pure padding
    (ISSUE 5)."""
    if n <= 0:
        return 0
    for b in PREFILL_BUCKETS:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


class Scheduler:
    """Order + admission policy. The engine asks it what to do each step."""

    def __init__(self):
        self.waiting: list[Request] = []
        self.active: dict[int, Active] = {}
        self._order = itertools.count()
        self._admit_seq = itertools.count(1)

    @staticmethod
    def _key(req: Request):
        return (-req.priority, req.order)

    def _insert(self, req: Request):
        self.waiting.append(req)
        self.waiting.sort(key=self._key)

    def submit(self, req: Request):
        req.state = RequestState.QUEUED
        if req.order is None:
            req.order = next(self._order)
        self._insert(req)

    def requeue(self, req: Request):
        """Re-queue a preempted request.  Keeps its original submission
        order (head of its priority class among later arrivals) and its
        PREEMPTED state — ``pop_expired`` never sheds a request that
        already holds generated tokens."""
        self._insert(req)

    def admit(self, budget: Union[int, Callable[[Request], bool]]
              ) -> list[Request]:
        """Priority-then-FCFS admission under a resource budget.

        ``budget`` is either a free-slot count (the slot-cache path) or a
        reservation policy called on the queue head — it commits resources
        (pages + a block-table row in the paged path; possibly after
        preempting a victim) and returns whether the request was admitted.
        Order is strict: the first request that does not fit stops admission
        (no skipping), so exhaustion defers rather than reorders within and
        across priority classes.
        """
        out = []
        if callable(budget):
            while self.waiting and budget(self.waiting[0]):
                out.append(self.waiting.pop(0))
        else:
            while self.waiting and budget > 0:
                out.append(self.waiting.pop(0))
                budget -= 1
        for req in out:
            req.state = RequestState.PREFILL
        return out

    def pop_expired(self, now: float) -> list[Request]:
        """Remove and return queued requests whose queue deadline has
        passed.  Preempted requests are exempt — their deadline was met at
        first admission and they hold generated tokens."""
        expired = [r for r in self.waiting
                   if r.queue_deadline is not None and now > r.queue_deadline
                   and r.state is RequestState.QUEUED]
        for r in expired:
            self.waiting.remove(r)
        return expired

    def activate(self, req: Request, slot: int) -> Active:
        a = Active(req=req, slot=slot, admit_seq=next(self._admit_seq))
        self.active[slot] = a
        return a

    def retire(self, slot: int) -> Active:
        return self.active.pop(slot)

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a still-queued (or preempted-and-requeued) request."""
        for i, req in enumerate(self.waiting):
            if req.rid == rid:
                del self.waiting[i]
                return req
        return None

    def find_active(self, rid: int) -> Optional[tuple[int, Active]]:
        """(row, Active) for an in-flight request, or None."""
        for row, a in self.active.items():
            if a.req.rid == rid:
                return row, a
        return None

    def preemption_victim(self, min_priority: int) -> Optional[int]:
        """Row of the best victim for a priority-``min_priority`` admission:
        lowest priority strictly below it, most-recently-admitted within
        that class.  None when nothing is eligible (preempting an equal or
        higher class would livelock)."""
        best = None
        for row, a in self.active.items():
            if a.req.priority >= min_priority:
                continue
            if a.pending_prefill:
                # mid-prefill rows are not offloadable: the host snapshot
                # covers ``lengths`` tokens, which for these rows is a
                # partially-written prompt — skip them (ISSUE 10)
                continue
            key = (a.req.priority, -a.admit_seq)
            if best is None or key < best[0]:
                best = (key, row)
        return None if best is None else best[1]

    def plan_chunks(self, budget: Optional[int], *,
                    reserve: int = 1) -> dict[int, int]:
        """Token-budget packing for one fused step (ISSUE 10/DESIGN.md §18).

        Every active row past its prefill decodes this step and claims
        ``reserve`` tokens up front (1 plain, k+1 under speculation); the
        remaining budget is dealt to mid-prefill rows as prompt chunks in
        (priority desc, submission order asc) sequence — strict, like
        ``admit``: the first row the budget cannot feed stops the deal, so
        exhaustion defers rather than reorders.  ``budget=None`` is
        unbudgeted: each pending row gets its whole remaining prompt.
        Returns {row: chunk_len} for the prefill rows scheduled this step.
        """
        pending = [(row, a) for row, a in self.active.items()
                   if a.pending_prefill]
        pending.sort(key=lambda e: (-e[1].req.priority, e[1].req.order))
        n_decode = len(self.active) - len(pending)
        remaining = (None if budget is None
                     else max(0, budget - n_decode * reserve))
        plan: dict[int, int] = {}
        for row, a in pending:
            need = a.prefill_end - a.prefill_pos
            take = need if remaining is None else min(need, remaining)
            if take <= 0:
                break
            plan[row] = take
            if remaining is not None:
                remaining -= take
        return plan

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.active
