"""Continuous-batching scheduler: FCFS admission into decode slots, bucketed
prefill lengths (bounded jit recompiles), per-request latency accounting."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Union

from repro.serving.sampler import SamplingParams


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    arrival: float = 0.0


@dataclasses.dataclass
class Finished:
    rid: int
    prompt_len: int
    output: list[int]
    arrival: float
    t_first_token: float
    t_done: float

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.arrival

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival


@dataclasses.dataclass
class Active:
    req: Request
    slot: int
    output: list[int] = dataclasses.field(default_factory=list)
    t_first_token: float = 0.0


PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_len(n: int) -> int:
    for b in PREFILL_BUCKETS:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


class Scheduler:
    """Order + admission policy. The engine asks it what to do each step."""

    def __init__(self):
        self.waiting: deque[Request] = deque()
        self.active: dict[int, Active] = {}

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self, budget: Union[int, Callable[[Request], bool]]
              ) -> list[Request]:
        """FCFS admission under a resource budget.

        ``budget`` is either a free-slot count (the slot-cache path) or a
        reservation policy called on the queue head — it commits resources
        (pages + a block-table row in the paged path) and returns whether the
        request was admitted.  FCFS is strict: the first request that does
        not fit stops admission (no skipping), so exhaustion defers rather
        than reorders.
        """
        out = []
        if callable(budget):
            while self.waiting and budget(self.waiting[0]):
                out.append(self.waiting.popleft())
        else:
            while self.waiting and budget > 0:
                out.append(self.waiting.popleft())
                budget -= 1
        return out

    def activate(self, req: Request, slot: int) -> Active:
        a = Active(req=req, slot=slot)
        self.active[slot] = a
        return a

    def retire(self, slot: int) -> Active:
        return self.active.pop(slot)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.active
