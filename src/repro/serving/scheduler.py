"""Continuous-batching scheduler: FCFS admission into decode slots, bucketed
prefill lengths (bounded jit recompiles), per-request lifecycle tracking."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional, Union

from repro.serving.api import RequestOutput, RequestState
from repro.serving.sampler import SamplingParams

# Backwards-compatible alias: the engine used to return ``Finished`` records;
# the redesigned API calls the same record ``RequestOutput`` (serving/api.py).
Finished = RequestOutput


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    arrival: float = 0.0
    # per-request stop criteria (ISSUE 3): extra stop token ids beyond eos,
    # and an eos opt-out for benchmark-style fixed-length generation
    stop_token_ids: tuple[int, ...] = ()
    ignore_eos: bool = False
    state: RequestState = RequestState.QUEUED


@dataclasses.dataclass
class Active:
    req: Request
    slot: int
    output: list[int] = dataclasses.field(default_factory=list)
    t_first_token: float = 0.0


PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_len(n: int) -> int:
    """Smallest prefill bucket holding ``n`` tokens; multiples of 4096 past
    the bucket table.  ``n <= 0`` is 0 — there is nothing to prefill, and the
    old behaviour (pad 0 up to 32) silently prefilled a block of pure padding
    (ISSUE 5)."""
    if n <= 0:
        return 0
    for b in PREFILL_BUCKETS:
        if n <= b:
            return b
    return -(-n // 4096) * 4096


class Scheduler:
    """Order + admission policy. The engine asks it what to do each step."""

    def __init__(self):
        self.waiting: deque[Request] = deque()
        self.active: dict[int, Active] = {}

    def submit(self, req: Request):
        req.state = RequestState.QUEUED
        self.waiting.append(req)

    def admit(self, budget: Union[int, Callable[[Request], bool]]
              ) -> list[Request]:
        """FCFS admission under a resource budget.

        ``budget`` is either a free-slot count (the slot-cache path) or a
        reservation policy called on the queue head — it commits resources
        (pages + a block-table row in the paged path) and returns whether the
        request was admitted.  FCFS is strict: the first request that does
        not fit stops admission (no skipping), so exhaustion defers rather
        than reorders.
        """
        out = []
        if callable(budget):
            while self.waiting and budget(self.waiting[0]):
                out.append(self.waiting.popleft())
        else:
            while self.waiting and budget > 0:
                out.append(self.waiting.popleft())
                budget -= 1
        for req in out:
            req.state = RequestState.PREFILL
        return out

    def activate(self, req: Request, slot: int) -> Active:
        a = Active(req=req, slot=slot)
        self.active[slot] = a
        return a

    def retire(self, slot: int) -> Active:
        return self.active.pop(slot)

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a still-queued request (abort-before-admission)."""
        for i, req in enumerate(self.waiting):
            if req.rid == rid:
                del self.waiting[i]
                return req
        return None

    def find_active(self, rid: int) -> Optional[tuple[int, Active]]:
        """(row, Active) for an in-flight request, or None."""
        for row, a in self.active.items():
            if a.req.rid == rid:
                return row, a
        return None

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.active
