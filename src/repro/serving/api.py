"""Public serving API types: the request lifecycle contract (DESIGN.md §11).

One place defines what a serving request *is* — everything the engine, the
HTTP front-end, the examples and the benchmarks previously re-derived from
positional kwargs:

* ``EngineConfig``     — the engine's construction surface (was 10 kwargs
                         duplicated across launch/examples/benchmarks).
* ``RequestState``     — QUEUED → PREFILL → RUNNING → FINISHED | ABORTED.
* ``FinishReason``     — why generation ended (OpenAI-compatible values).
* ``StreamEvent``      — one generated token of one request, as yielded by
                         ``Engine.stream()``; terminal events carry the
                         ``RequestOutput``.
* ``RequestOutput``    — a completed (or aborted) request with per-request
                         latency metrics (ttft / tpot / e2e latency).

``RequestOutput`` is the same record the pre-redesign engine returned as
``scheduler.Finished`` (kept as an alias there), extended with
``finish_reason``/``state`` — old callers keep working unchanged.
"""
from __future__ import annotations

import dataclasses
import enum

import jax.numpy as jnp

from repro.models import layers as L
from repro.serving.kv_quant import KVQuantConfig
from repro.serving.spec_decode import SpecConfig


class RequestState(str, enum.Enum):
    """Lifecycle of one serving request inside the engine."""
    QUEUED = "queued"        # submitted, waiting for slot/page admission
    PREFILL = "prefill"      # admitted; prompt KV being written
    RUNNING = "running"      # decoding, first token already produced
    PREEMPTED = "preempted"  # evicted by a higher-priority request; its KV
                             # pages are offloaded to host memory and it is
                             # back in the queue awaiting restore
    FINISHED = "finished"    # completed via stop token / eos / length
    ABORTED = "aborted"      # cancelled via Engine.abort()


class FinishReason(str, enum.Enum):
    """Why a request stopped — values match the OpenAI completions API
    where one exists (stop/length); shed/stall are overload outcomes
    (DESIGN.md §14)."""
    STOP = "stop"            # eos (unless ignore_eos) or a stop_token_id
    LENGTH = "length"        # hit max_new_tokens
    ABORT = "abort"          # Engine.abort() mid-flight or while queued
    SHED = "shed"            # queue deadline expired before admission
                             # (graceful overload shedding -> HTTP 503)
    STALL = "stall"          # engine worker watchdog fired: a step exceeded
                             # the stall timeout; in-flight requests fail
                             # instead of hanging their clients


class QueueFullError(RuntimeError):
    """``Engine.submit`` under bounded admission: the wait queue is at
    ``EngineConfig.max_queued``.  The HTTP front-end maps this to 429 with
    a ``Retry-After`` header (``retry_after_s``)."""

    def __init__(self, msg: str, retry_after_s: float = 1.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Single-sourced engine construction config.

    Every field previously travelled as an ``Engine.__init__`` kwarg,
    re-spelled independently by ``launch/serve.py``, both serving examples
    and the benchmarks.  ``Engine(model, params, EngineConfig(...))`` is the
    supported spelling; the old kwargs remain as a deprecated shim.
    """
    batch_slots: int = 8
    max_len: int = 512
    kernels: L.KernelConfig = L.DEFAULT_KERNELS
    eos_id: int = 1
    cache: str | None = None          # None -> kernels.cache_layout
    page_size: int = 16
    num_pages: int | None = None      # None -> batch_slots * ceil(max_len/page)
    cache_dtype: object = None        # None -> kv_cache.DEFAULT_CACHE_DTYPE
    seed: int = 0
    # KV quantization (DESIGN.md §12): None, a KVQuantConfig, or a dtype
    # string shorthand ("int8" / "bf16" / "fp32" — normalized to a config)
    kv_quant: object = None
    # paged layout: derive num_pages from a byte budget (payload + scale
    # pools) instead of the capacity-equivalent default — the lever that
    # turns int8 KV into a ~2x (vs bf16) / ~4x (vs fp32) deeper page pool
    page_pool_bytes: int | None = None
    # ---- overload resilience (DESIGN.md §14) ----
    # bounded admission: submit() raises QueueFullError once this many
    # requests are waiting (None = unbounded, the pre-§14 behaviour)
    max_queued: int | None = None
    # default per-request queue deadline: a request not admitted within
    # this many seconds of submit is shed (FinishReason.SHED); per-request
    # ``queue_timeout_s`` on submit() overrides it
    default_queue_timeout_s: float | None = None
    # paged layout: allow a higher-priority request that cannot reserve
    # pages to preempt a lower-priority victim (offload its pages to host
    # memory and re-queue it) instead of deferring behind it
    preemption: bool = True
    # injectable clock (serving/clock.py) — every serving deadline and
    # timestamp reads through it; None -> the real SystemClock
    clock: object = None
    # serving fault injector (serving/faults.py::FaultInjector) consulted
    # at the top of every Engine.step(); None in production
    faults: object = None
    # ---- observability (DESIGN.md §15) ----
    # metrics=False swaps the engine's registry for the no-op NullRegistry
    # (the zero-cost opt-out); EngineStats then reads all-zero
    metrics: bool = True
    # step-span tracer (serving/tracing.py::Tracer) recording per-request
    # lifecycle + per-step spans for Perfetto export; None = tracing off
    tracer: object = None
    # ---- speculative decoding (DESIGN.md §16) ----
    # a SpecConfig turns the decode loop into propose-k / batched-verify
    # steps emitting up to k+1 tokens each; None = plain one-token decode
    speculation: SpecConfig | None = None
    # paged layout: directory to persist/restore the hashed prefix-cache
    # index + page payloads across engine restarts (DESIGN.md §16); the
    # engine loads it at construction when the directory exists and
    # ``Engine.save_prefix_cache()`` writes it
    prefix_cache_path: str | None = None
    # ---- tensor parallelism (DESIGN.md §17) ----
    # device mesh this engine spans (serving/parallel.py): None or (1,) is
    # today's single-device engine, (N,) shards GPTQ weights head-/N-major
    # and the KV page pools per device with shard_map around the paged
    # kernels.  Paged layout only; page budgets (num_pages /
    # page_pool_bytes) are interpreted *per device* — each device's pool
    # holds its head-slice of the same global page ids
    mesh_shape: tuple | None = None
    # mesh axis name the row-parallel all-reduce epilogue psums over
    tp_axis: str = "model"
    # ---- fused-step execution (ISSUE 10, DESIGN.md §18) ----
    # token budget of one fused engine step: decode/verify rows claim their
    # tokens first, the remainder is handed to waiting prompts as prefill
    # chunks riding the same jitted program.  None = no budget — a whole
    # remaining prompt prefills in one chunk (still via the fused program)
    max_step_tokens: int | None = None

    def __post_init__(self):
        if self.batch_slots <= 0:
            raise ValueError(f"batch_slots must be > 0, got {self.batch_slots}")
        if self.max_len <= 0:
            raise ValueError(f"max_len must be > 0, got {self.max_len}")
        if self.page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {self.page_size}")
        if self.num_pages is not None and self.num_pages <= 0:
            raise ValueError(
                f"num_pages must be > 0 (or None for the capacity-equivalent "
                f"default), got {self.num_pages}")
        layout = getattr(self.cache, "value", self.cache)
        if layout is not None and layout not in ("slot", "paged"):
            raise ValueError(f"unknown cache layout {self.cache!r}")
        if isinstance(self.kv_quant, str):
            # shorthand; KVQuantConfig rejects unknown dtype strings
            object.__setattr__(self, "kv_quant",
                               KVQuantConfig(dtype=self.kv_quant))
        if self.kv_quant is not None:
            if not isinstance(self.kv_quant, KVQuantConfig):
                raise ValueError(
                    f"kv_quant must be a KVQuantConfig or a dtype string, "
                    f"got {self.kv_quant!r}")
            if self.kv_quant.quantized:
                if self.cache_dtype is not None:
                    raise ValueError(
                        f"kv_quant='int8' stores int8 payloads — "
                        f"cache_dtype={self.cache_dtype!r} would be ignored; "
                        f"pass one or the other")
                if self.kv_quant.granularity != "token":
                    raise ValueError(
                        "the engine's fused write path uses per-token "
                        "scales; per-page granularity is served by the "
                        "PagedCache data-path API only")
            elif (self.cache_dtype is not None
                  and jnp.dtype(self.cache_dtype) != self.kv_quant.jnp_dtype):
                raise ValueError(
                    f"kv_quant passthrough dtype {self.kv_quant.dtype!r} "
                    f"conflicts with cache_dtype={self.cache_dtype!r}")
        if self.page_pool_bytes is not None:
            if self.page_pool_bytes <= 0:
                raise ValueError(
                    f"page_pool_bytes must be > 0, got {self.page_pool_bytes}")
            if self.num_pages is not None:
                raise ValueError(
                    "pass either num_pages or page_pool_bytes, not both")
        if self.max_queued is not None and self.max_queued <= 0:
            raise ValueError(
                f"max_queued must be > 0 (or None for unbounded), got "
                f"{self.max_queued}")
        if (self.default_queue_timeout_s is not None
                and self.default_queue_timeout_s <= 0):
            raise ValueError(
                f"default_queue_timeout_s must be > 0, got "
                f"{self.default_queue_timeout_s}")
        if self.speculation is not None:
            if not isinstance(self.speculation, SpecConfig):
                raise ValueError(
                    f"speculation must be a SpecConfig, got "
                    f"{self.speculation!r}")
            if self.speculation.k >= self.max_len:
                raise ValueError(
                    f"speculation k={self.speculation.k} must be < "
                    f"max_len={self.max_len}")
        if (self.prefix_cache_path is not None
                and not isinstance(self.prefix_cache_path, str)):
            raise ValueError(
                f"prefix_cache_path must be a directory path string, got "
                f"{self.prefix_cache_path!r}")
        if self.mesh_shape is not None:
            dims = tuple(self.mesh_shape)
            if not dims or any(not isinstance(d, int) or d <= 0
                               for d in dims):
                raise ValueError(
                    f"mesh_shape must be a non-empty tuple of positive "
                    f"ints, got {self.mesh_shape!r}")
            object.__setattr__(self, "mesh_shape", dims)
            tp = 1
            for d in dims:
                tp *= d
            if tp > 1:
                if layout == "slot" or (layout is None and getattr(
                        self.kernels.cache_layout, "value",
                        self.kernels.cache_layout) == "slot"):
                    raise ValueError(
                        "tensor-parallel serving shards the KV page pools "
                        "— cache='paged' required with mesh_shape "
                        f"{dims}")
        if self.max_step_tokens is not None and self.max_step_tokens <= 0:
            raise ValueError(
                f"max_step_tokens must be > 0 (or None for unbudgeted "
                f"prefill chunks), got {self.max_step_tokens}")
        if not self.tp_axis or not isinstance(self.tp_axis, str):
            raise ValueError(
                f"tp_axis must be a non-empty axis name, got "
                f"{self.tp_axis!r}")


@dataclasses.dataclass
class RequestOutput:
    """A completed or aborted request, with request-level latency metrics.

    ``output`` holds the generated token ids (stop/eos token included when it
    caused the stop).  Aborted-while-queued requests have empty ``output``
    and ``t_first_token == 0.0``.
    """
    rid: int
    prompt_len: int
    output: list[int]
    arrival: float
    t_first_token: float
    t_done: float
    finish_reason: FinishReason | None = None
    # speculative decoding (DESIGN.md §16): draft tokens this request was
    # offered / kept across its verify steps (both 0 with speculation off)
    spec_proposed: int = 0
    spec_accepted: int = 0

    @property
    def state(self) -> RequestState:
        return (RequestState.ABORTED
                if self.finish_reason is FinishReason.ABORT
                else RequestState.FINISHED)

    @property
    def ttft(self) -> float:
        """Time to first token, from submission (0.0 when no token was ever
        produced — e.g. aborted while still queued)."""
        if not self.t_first_token:
            return 0.0
        return self.t_first_token - self.arrival

    @property
    def tpot(self) -> float:
        """Time per output token over the decode phase (post-first-token).

        Deliberately normalized by *emitted tokens*, not engine steps — a
        speculative verify step that lands k+1 tokens reads as k+1 cheap
        tokens here, keeping tpot comparable between spec-on and spec-off
        runs (DESIGN.md §16)."""
        n = len(self.output)
        if n <= 1 or not self.t_first_token:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens this request accepted (0.0
        when it never saw a speculative step)."""
        if not self.spec_proposed:
            return 0.0
        return self.spec_accepted / self.spec_proposed

    @property
    def latency(self) -> float:
        """End-to-end latency, submission to completion."""
        return self.t_done - self.arrival


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One token of one request, yielded by ``Engine.stream()``.

    ``index`` is the token's position in the request's output.  Terminal
    events set ``finish_reason`` and carry the full ``RequestOutput``; an
    abort's terminal event has ``token is None`` (nothing was sampled).
    """
    rid: int
    token: int | None
    index: int
    finish_reason: FinishReason | None = None
    output: RequestOutput | None = None
