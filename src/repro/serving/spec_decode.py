"""Speculative decoding subsystem (DESIGN.md §16).

Decode throughput is bounded by one token per model step; this module lifts
that to up to ``k + 1`` tokens per *verify* step.  A ``Speculator`` proposes
``k`` draft tokens per running request, the engine scores all drafts plus
the current input token as a (k+1)-token chunk row of its fused step
(``Engine._fused_step_impl``, ISSUE 10 — the paged layout routes it through
the chunked write-masked ``paged_prefill`` kernel), and
``sampler.accept_speculative`` keeps the longest valid prefix plus one
bonus/resample token.  Rollback is free by
construction: speculative KV writes land at positions ``[L, L + wl)`` but
``seq_lens`` / the host page-length mirror only advance to the accepted
position, so rejected tokens are never attended and are overwritten by the
next verify span (the engine's write-span accounting guarantees coverage).

Two built-in proposers:

* ``NGramSpeculator`` — model-free prompt-lookup: the longest suffix
  n-gram of the request's own token history that occurred earlier predicts
  its historical continuation.  Pure host-side, zero extra parameters.
* ``DraftModelSpeculator`` — a smaller registry config run on its own slot
  cache; drafts come from a K-step ``lax.scan`` and stay on device,
  together with the draft distribution ``q`` needed for rejection sampling
  under temperature.

Module-level imports deliberately stop at ``sampler`` — ``api.py`` imports
``SpecConfig`` from here, so anything engine/scheduler-side is imported
lazily inside methods.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import filter_logits

MAX_SPEC_K = 16


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs, carried by ``EngineConfig(speculation=)``.

    ``method`` selects the proposer: ``"ngram"`` (prompt lookup, default) or
    ``"draft"`` (small draft model — needs ``draft_arch`` naming a registry
    config, or an injected ``draft_model``/``draft_params`` pair).  ``k`` is
    the draft length per verify step.  ``draft_smoke`` builds the draft
    arch through ``smoke_config`` (tests / CI); real launches set it False.
    """
    method: str = "ngram"
    k: int = 4
    ngram_max: int = 4
    ngram_min: int = 1
    draft_arch: Optional[str] = None
    draft_smoke: bool = True
    draft_model: object = None
    draft_params: object = None
    draft_seed: int = 0
    # tolerance-aware greedy acceptance (ISSUE 10 satellite): accept a draft
    # whose target logit is within this of the row max instead of requiring
    # the exact argmax — absorbs the ~1e-7 matmul-vs-GEMV accumulation gap
    # (ROADMAP §spec).  None = exact argmax matching.
    greedy_accept_tol: Optional[float] = None

    def __post_init__(self):
        if self.method not in ("ngram", "draft"):
            raise ValueError(
                f"speculation method must be 'ngram' or 'draft', "
                f"got {self.method!r}")
        if self.greedy_accept_tol is not None \
                and not self.greedy_accept_tol >= 0.0:
            raise ValueError(
                f"greedy_accept_tol must be >= 0 (or None for exact argmax "
                f"acceptance), got {self.greedy_accept_tol}")
        if not 1 <= self.k <= MAX_SPEC_K:
            raise ValueError(
                f"speculation k must be in [1, {MAX_SPEC_K}], got {self.k}")
        if not 1 <= self.ngram_min <= self.ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"({self.ngram_min}, {self.ngram_max})")
        if self.method == "draft":
            has_injected = self.draft_model is not None \
                and self.draft_params is not None
            if self.draft_arch is None and not has_injected:
                raise ValueError(
                    "speculation method 'draft' needs draft_arch (a registry "
                    "config name) or an injected draft_model + draft_params")


@dataclasses.dataclass
class Proposal:
    """One propose() result: per-row drafts (host or device (B, K) int32),
    host draft lengths (rows may propose fewer than K; 0 = no drafts, the
    verify step degrades to a plain decode step for that row), and — draft-
    model proposers only — the device draft distribution q (B, K, V) that
    rejection sampling scores against."""
    drafts: object
    draft_lens: np.ndarray
    probs: object = None


class Speculator:
    """Proposer interface.  ``rows`` maps engine row -> (rid, context
    token list, per-row draft cap); ``samp`` carries the host staging
    arrays (greedy, temps, top_ks, top_ps) when the batch isn't all-greedy
    (draft-model proposers sample their drafts under the same per-row
    parameters the target uses)."""
    k: int = 0

    def propose(self, rows: dict, *, all_greedy: bool,
                samp=None) -> Proposal:
        raise NotImplementedError

    def observe(self, row: int, rid: int, n_accepted: int) -> None:
        """Verify outcome for a still-running row (draft-model proposers
        advance their cache coverage bookkeeping here)."""

    def invalidate(self, row: int) -> None:
        """Row retired / preempted — drop any per-row state."""


# --------------------------------------------------------------------- ngram
def ngram_propose(ctx, k: int, ngram_max: int, ngram_min: int) -> list:
    """Prompt-lookup proposal: find the longest suffix n-gram (length
    ``ngram_max`` down to ``ngram_min``) of ``ctx`` that also occurs
    earlier, and return up to ``k`` tokens of the *most recent* earlier
    occurrence's continuation.  When the match overlaps the suffix (a
    periodic tail — the classic greedy repetition loop), the continuation
    reads through its own prediction, extrapolating the period to a full
    ``k`` tokens instead of truncating at the end of the context.  Empty
    list when nothing matches."""
    if k <= 0:
        return []
    n_hi = min(ngram_max, len(ctx) - 1)
    for n in range(n_hi, ngram_min - 1, -1):
        pattern = ctx[-n:]
        for i in range(len(ctx) - n - 1, -1, -1):
            if ctx[i:i + n] == pattern:
                ext = list(ctx)
                for j in range(k):
                    ext.append(ext[i + n + j])
                return ext[len(ctx):]
    return []


class NGramSpeculator(Speculator):
    """Model-free prompt-lookup proposer — suffix-match over the request's
    own prompt + generated tokens.  Entirely host-side; proposes variable-
    length drafts (often zero on non-repetitive text, which costs one
    ordinary decode step)."""

    def __init__(self, cfg: SpecConfig, batch_rows: int):
        self.k = cfg.k
        self.ngram_max = cfg.ngram_max
        self.ngram_min = cfg.ngram_min
        self.batch_rows = batch_rows

    def propose(self, rows, *, all_greedy, samp=None) -> Proposal:
        drafts = np.zeros((self.batch_rows, self.k), np.int32)
        lens = np.zeros((self.batch_rows,), np.int32)
        for row, (_rid, ctx, cap) in rows.items():
            got = ngram_propose(ctx, min(self.k, cap),
                                self.ngram_max, self.ngram_min)
            drafts[row, :len(got)] = got
            lens[row] = len(got)
        return Proposal(drafts=drafts, draft_lens=lens)


# --------------------------------------------------------------- draft model
class DraftModelSpeculator(Speculator):
    """Small-model proposer on its own slot-layout cache.

    Per-row state is (rid, covered): ``covered`` counts context positions
    written into the draft cache.  The invariant kept across verify steps is
    ``covered ∈ {want, want - 1}`` where ``want = len(ctx) - 1`` (the last
    context token is the next input, not yet written — same convention as
    the target engine).  A one-token masked catch-up step closes the
    deficit (it is exactly 1 when every draft accepted last round, because
    the propose scan writes only K positions for K drafts); anything else —
    fresh row, preemption gap, rid reuse — re-prefills the row from
    scratch.  Proposing is one jitted ``lax.scan`` of K decode steps that
    returns the drafts and (when sampling) the filtered draft distribution
    q for rejection sampling; drafts never leave the device on this path.
    """

    def __init__(self, cfg: SpecConfig, model, params, batch_rows: int,
                 max_len: int, *, kernels):
        self.k = cfg.k
        self.model, self.params = model, params
        self.kernels = kernels
        self.batch_rows = batch_rows
        self.max_len = max_len
        self.cache = model.init_cache(batch_rows, max_len,
                                      dtype=jnp.float32)
        self._row_rid = np.full((batch_rows,), -1, np.int64)
        self._covered = np.zeros((batch_rows,), np.int64)
        self._ctx_len = np.zeros((batch_rows,), np.int64)
        self.rng = jax.random.key(cfg.draft_seed ^ 0x5BEC)

        cpu = jax.default_backend() == "cpu"
        donate = () if cpu else (1,)                    # draft cache tree
        self._scan = jax.jit(
            functools.partial(self._scan_impl, model, kernels, cfg.k),
            static_argnames=("all_greedy",), donate_argnums=donate)
        self._catchup = jax.jit(
            functools.partial(self._catchup_impl, model, kernels),
            donate_argnums=donate)
        self._prefill = jax.jit(
            functools.partial(self._prefill_impl, model, kernels),
            donate_argnums=() if cpu else (2,))         # row sub-cache
        self._read_row = jax.jit(self._read_row_impl)
        self._write_row = jax.jit(self._write_row_impl,
                                  donate_argnums=() if cpu else (0,))

    # ------------------------------------------------------------ jitted fns
    @staticmethod
    def _scan_impl(model, kernels, k, params, cache, seq_lens, first, live,
                   greedy, temps, top_ks, top_ps, keys, *,
                   all_greedy: bool = False):
        """K chained draft decode steps.  Writes K positions
        ``[covered, covered + K)`` holding ``[ctx[-1], d_1 .. d_{K-1}]`` —
        after the scan the draft cache covers the full context plus K - 1
        speculative tokens.  Returns drafts (B, K) and, when sampling, the
        filtered draft distribution q (B, K, V)."""
        wl = live.astype(jnp.int32)
        need_probs = not all_greedy

        def body(carry, key):
            cache, seq_lens, tok = carry
            logits, cache, _ = model.apply(
                params, {"tokens": tok}, kernels=kernels, cache=cache,
                seq_lens=seq_lens, mode="decode", write_lens=wl)
            lg = logits[:, -1]
            seq_lens = seq_lens + wl
            if all_greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                q = jnp.zeros((), jnp.float32)
            else:
                lf = filter_logits(lg, temps, top_ks, top_ps)
                q = jax.nn.softmax(lf, axis=-1)
                rkeys = jax.random.split(key, lg.shape[0])
                sampled = jax.vmap(
                    lambda kk, row: jax.random.categorical(
                        kk, row[None], axis=-1)[0])(rkeys, lf)
                nxt = jnp.where(greedy,
                                jnp.argmax(lg, axis=-1),
                                sampled).astype(jnp.int32)
            nxt = jnp.where(live, nxt, 0)
            return (cache, seq_lens, nxt[:, None]), (nxt, q)

        keys = jax.random.split(keys, k)
        (cache, _, _), (drafts, qs) = jax.lax.scan(
            body, (cache, seq_lens, first), keys)
        drafts = jnp.transpose(drafts, (1, 0))              # (B, K)
        probs = None if not need_probs else jnp.transpose(qs, (1, 0, 2))
        return drafts, probs, cache

    @staticmethod
    def _catchup_impl(model, kernels, params, cache, seq_lens, tokens, wl):
        """One masked decode step writing the deficit token for rows whose
        coverage trails the context by one (write_lens 0 elsewhere)."""
        _, cache, _ = model.apply(
            params, {"tokens": tokens}, kernels=kernels, cache=cache,
            seq_lens=seq_lens, mode="decode", write_lens=wl)
        return cache

    @staticmethod
    def _prefill_impl(model, kernels, params, tokens, length, cache,
                      seq_lens):
        lengths = jnp.full((tokens.shape[0],), length, jnp.int32)
        _, cache, _ = model.prefill(
            params, {"tokens": tokens}, cache, seq_lens, kernels=kernels,
            true_lengths=lengths)
        return cache

    @staticmethod
    def _read_row_impl(cache, row):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, row, 1, axis=1)
            if x.ndim >= 2 else x, cache)

    @staticmethod
    def _write_row_impl(cache, sub, row):
        return jax.tree_util.tree_map(
            lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), row, axis=1)
            if full.ndim >= 2 else s, cache, sub)

    # ------------------------------------------------------------------ host
    def _prefill_row(self, row: int, ctx_prefix) -> None:
        """Bucketed re-prefill of one draft-cache row with ``ctx[:-1]``."""
        from repro.serving.scheduler import bucket_len

        n = len(ctx_prefix)
        blen = min(bucket_len(n), self.max_len)
        toks = np.zeros((1, blen), np.int32)
        toks[0, :n] = ctx_prefix
        sub = self._read_row(self.cache, jnp.asarray(row, jnp.int32))
        sub = self._prefill(self.params, jnp.asarray(toks),
                            jnp.asarray(n, jnp.int32), sub,
                            jnp.zeros((1,), jnp.int32))
        self.cache = self._write_row(self.cache, sub,
                                     jnp.asarray(row, jnp.int32))

    def propose(self, rows, *, all_greedy, samp=None) -> Proposal:
        b = self.batch_rows
        first = np.zeros((b, 1), np.int32)
        live = np.zeros((b,), np.bool_)
        for row, (rid, ctx, _cap) in rows.items():
            want = len(ctx) - 1
            if (self._row_rid[row] != rid
                    or not 0 <= want - self._covered[row] <= 1):
                self._prefill_row(row, ctx[:-1])
                self._row_rid[row] = rid
                self._covered[row] = want
            first[row, 0] = ctx[-1]
            live[row] = True
            self._ctx_len[row] = len(ctx)

        # catch-up: rows trailing the context by one feed ctx[-2] (the
        # second-to-last accepted token) through a masked single-token step
        cwl = np.zeros((b,), np.int32)
        ctoks = np.zeros((b, 1), np.int32)
        for row, (_rid, ctx, _cap) in rows.items():
            if self._covered[row] == len(ctx) - 2:
                ctoks[row, 0] = ctx[-2]
                cwl[row] = 1
        if cwl.any():
            seq_cat = jnp.asarray(np.where(cwl > 0, self._covered, 0)
                                  .astype(np.int32))
            self.cache = self._catchup(
                self.params, self.cache, seq_cat, jnp.asarray(ctoks),
                jnp.asarray(cwl))
            self._covered += cwl

        seq = jnp.asarray(np.where(live, self._covered, 0).astype(np.int32))
        self.rng, sub = jax.random.split(self.rng)
        if all_greedy:
            sarr = (None,) * 4
        else:
            greedy, temps, top_ks, top_ps = samp
            sarr = (jnp.asarray(greedy), jnp.asarray(temps),
                    jnp.asarray(top_ks), jnp.asarray(top_ps))
        drafts, probs, self.cache = self._scan(
            self.params, self.cache, seq, jnp.asarray(first),
            jnp.asarray(live), *sarr, sub, all_greedy=all_greedy)
        lens = np.zeros((b,), np.int32)
        for row, (_rid, _ctx, cap) in rows.items():
            lens[row] = min(self.k, cap)
        return Proposal(drafts=drafts, draft_lens=lens, probs=probs)

    def observe(self, row: int, rid: int, n_accepted: int) -> None:
        if self._row_rid[row] != rid:
            return
        # scan wrote context + K-1 speculative tokens; accepted tokens up to
        # that horizon are now verified context
        self._covered[row] = self._ctx_len[row] + min(n_accepted, self.k - 1)

    def invalidate(self, row: int) -> None:
        self._row_rid[row] = -1
        self._covered[row] = 0


# -------------------------------------------------------------------- factory
def make_speculator(spec: SpecConfig, model, config, *,
                    kernels) -> Speculator:
    """Build the proposer for an engine.  ``config`` is the ``EngineConfig``
    (batch geometry); ``model`` the target model (vocab compatibility)."""
    if spec.method == "ngram":
        return NGramSpeculator(spec, config.batch_slots)

    if spec.draft_model is not None:
        dmodel, dparams = spec.draft_model, spec.draft_params
    else:
        from repro.configs import get_config, smoke_config
        from repro.models import build_model

        dcfg = smoke_config(spec.draft_arch) if spec.draft_smoke \
            else get_config(spec.draft_arch)
        dmodel = build_model(dcfg)
        dparams = dmodel.init(jax.random.key(spec.draft_seed ^ 0xD9AF))
    if dmodel.cfg.vocab_size != model.cfg.vocab_size:
        raise ValueError(
            f"draft model vocab ({dmodel.cfg.vocab_size}) must match the "
            f"target vocab ({model.cfg.vocab_size})")
    # headroom: paged targets can run to ceil(max_len/page)*page tokens, and
    # the scan parks up to k - 1 speculative tokens past the covered context
    cap = -(-config.max_len // config.page_size) * config.page_size
    return DraftModelSpeculator(spec, dmodel, dparams, config.batch_slots,
                                cap + spec.k + 1, kernels=kernels)
