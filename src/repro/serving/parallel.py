"""Tensor-parallel paged serving (DESIGN.md §17): one ``Engine`` spanning a
device mesh.

The engine's single fused-step program (ISSUE 10) is wrapped in
``shard_map`` over a 1-D mesh so the GPTQ weights and the KV page pools are
*partitioned* across devices while the scheduler, block tables and sampling
state stay replicated.  Layout (Megatron col->row inside every block,
reusing the parameter role sets from ``sharding/partition.py``):

* **col-parallel** (``wq``/``wk``/``wv``/``w_gate``/``w_up``): output (N)
  axis sharded — each device computes its own head / d_ff slice from
  replicated activations.  For GPTQ leaves that means ``qweight``/
  ``scales``/``qzeros`` columns (the qzeros nibble packing needs the
  per-device N to stay a multiple of 8).
* **row-parallel** (``wo``/``w_down``): input (K) axis sharded — each
  device already holds the matching slice of the upstream activations
  (its heads / its d_ff lanes) and produces a *partial* matmul that
  ``layers.tp_all_reduce`` (a psum over the TP axis, armed by
  ``layers.tp_epilogue`` at trace time) completes.  Act-order ``perm``
  permutes the full K axis and cannot cross shards — rejected.
* **KV page pools**: the ``num_kv_heads`` axis of ``k_pages``/``v_pages``
  (and the int8 ``k_scales``/``v_scales`` pools) is sharded.  Page *ids*
  stay global — every device owns the head-slice of every page — so the
  host-side ``PagedCache`` bookkeeping (free lists, refcounts, COW, the
  hashed prefix index, offload/restore) is byte-for-byte the single-device
  code, and block tables are replicated operands.
* everything else (embedding, norms, tied head, q/k-norm scales, sampling
  state, PRNG keys) is replicated, so the post-psum activations — and
  therefore logits, argmax and samples — are identical on every device and
  the replicated out-specs are sound by construction.

The shard_map body runs the *same* ``Engine._fused_step_impl`` code (one
program for decode, chunked prefill and spec-verify — ISSUE 10) against a
local model whose config carries the per-device head counts (``gqa_apply``
reshapes with ``cfg.num_heads`` / ``cfg.num_kv_heads``), which keeps the
Pallas ``paged_attention`` / ``paged_prefill`` / GPTQ GEMV kernels entirely
unchanged: they see a smaller model.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.gptq import QuantizedLinear
from repro.models import layers as L
from repro.sharding.partition import COL_PARALLEL, ROW_PARALLEL


def _shard_map(body, mesh, in_specs, out_specs):
    """shard_map with the replication-check compat shim (same dance as
    ``models/ffn.py``'s expert-parallel path): the out-specs are replicated
    by construction (see module docstring), which the checker cannot
    prove through psum-free branches."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:                              # pragma: no cover - old jax
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:                           # pragma: no cover - old jax
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def _entry_name(entry) -> str:
    """Dict key / dataclass field name of one tree-path entry."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


@dataclasses.dataclass(frozen=True)
class TPContext:
    """Everything the engine needs to run one model tensor-parallel."""
    mesh: Mesh
    axis: str
    tp: int
    local_model: object          # LM with per-device head counts
    param_specs: object          # PartitionSpec tree matching the params


# ------------------------------------------------------------- spec building
def _matrix_spec(ndim: int, shard_axis: int, axis: str) -> P:
    """P over an ndim-array sharding exactly ``shard_axis`` (negative,
    counted from the end so group-stacked leading dims stay replicated)."""
    dims: list = [None] * ndim
    dims[ndim + shard_axis] = axis
    return P(*dims)


def param_specs(params, axis: str, tp: int):
    """PartitionSpec tree for a (possibly GPTQ-quantized, possibly
    group-stacked) parameter tree.  Raises ``ValueError`` naming the
    offending leaf when a shard axis does not divide by ``tp`` or an
    act-order permutation sits on a row-parallel projection."""

    def spec(path, leaf):
        names = [_entry_name(e) for e in path]
        role = next((n for n in reversed(names)
                     if n in COL_PARALLEL or n in ROW_PARALLEL), None)
        if role is None:
            return P()
        where = "/".join(names)
        leafname = names[-1]
        if leafname == "perm":
            if role in ROW_PARALLEL:
                raise ValueError(
                    f"{where}: act-order perm permutes the full K axis and "
                    f"cannot be sharded row-parallel; quantize with "
                    f"act_order=False for tensor-parallel serving")
            return P()                      # col-parallel: K replicated
        # dense {w, b} and quantized {qweight, scales, qzeros, bias} leaves:
        # col-parallel shards the last (N) axis, row-parallel the K axis
        # (second-to-last for matrices).  A row-parallel bias would be
        # added once per shard and then psum-multiplied by tp — reject it
        # (wo / w_down carry no bias in this codebase).
        if role in ROW_PARALLEL and leafname in ("b", "bias"):
            raise ValueError(
                f"{where}: bias on a row-parallel projection would be "
                f"summed tp={tp} times by the all-reduce epilogue")
        shard_axis = -1 if role in COL_PARALLEL else -2
        if leaf.ndim < -shard_axis:
            return P()
        dim = leaf.shape[shard_axis]
        if dim % tp:
            raise ValueError(
                f"{where}: axis of size {dim} does not divide tp={tp} "
                f"(shape {tuple(leaf.shape)})")
        return _matrix_spec(leaf.ndim, shard_axis, axis)

    return jax.tree_util.tree_map_with_path(spec, params)


def cache_specs(cache, axis: str, tp: int = 1):
    """PartitionSpec tree for a paged cache tree: the ``num_kv_heads`` axis
    of the page pools (``k_pages``/``v_pages``: ``(..., pages, page_size,
    Hkv, D)``) and scale pools (``k_scales``/``v_scales``: ``(..., pages,
    page_size, Hkv)``) is sharded; page ids stay global."""

    def spec(path, leaf):
        name = _entry_name(path[-1]) if path else ""
        if name.endswith("_pages"):
            shard_axis = -2
        elif name.endswith("_scales"):
            shard_axis = -1
        else:
            raise ValueError(
                f"unrecognized paged-cache leaf {name!r} — tensor-parallel "
                f"serving knows k/v_pages and k/v_scales pools only")
        if leaf.shape[shard_axis] % tp:
            raise ValueError(
                f"{name}: num_kv_heads={leaf.shape[shard_axis]} does not "
                f"divide tp={tp}")
        return _matrix_spec(leaf.ndim, shard_axis, axis)

    return jax.tree_util.tree_map_with_path(spec, cache)


# ------------------------------------------------------------------ building
def build_tp_context(model, params, tp: int, axis: str = "model") -> TPContext:
    """Validate the (model, params) pair for ``tp``-way tensor parallelism
    and return the mesh + local model + parameter specs the engine wires
    into its jitted programs.  Pure host-side: nothing is device_put here."""
    if tp <= 0:
        raise ValueError(f"tp must be >= 1, got {tp}")
    avail = len(jax.devices())
    if tp > avail:
        raise ValueError(
            f"tensor parallelism tp={tp} needs {tp} devices but only "
            f"{avail} are available (CPU runs: set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            f"jax initializes)")
    cfg = model.cfg
    if cfg.attn_type != "gqa" or cfg.family in ("ssm", "hybrid") \
            or getattr(cfg, "num_experts", 0):
        raise ValueError(
            "tensor-parallel serving supports full-attention GQA stacks "
            f"only, got family={cfg.family!r} attn_type={cfg.attn_type!r}")
    for field in ("num_heads", "num_kv_heads"):
        n = getattr(cfg, field)
        if n % tp:
            raise ValueError(
                f"{field}={n} does not divide tp={tp} — heads are the "
                f"tensor-parallel unit")
    local_cfg = dataclasses.replace(cfg, num_heads=cfg.num_heads // tp,
                                    num_kv_heads=cfg.num_kv_heads // tp)
    local_model = type(model)(local_cfg)
    mesh = Mesh(np.asarray(jax.devices()[:tp]), (axis,))
    return TPContext(mesh=mesh, axis=axis, tp=tp, local_model=local_model,
                     param_specs=param_specs(params, axis, tp))


def _device_put_tree(mesh, tree, specs):
    return jax.tree_util.tree_map(
        lambda leaf, s: jax.device_put(leaf, NamedSharding(mesh, s)),
        tree, specs)


def shard_params(ctx: TPContext, params):
    """Commit the parameter tree to its TP sharding (slices land on their
    owning device; replicated leaves are broadcast)."""
    return _device_put_tree(ctx.mesh, params, ctx.param_specs)


def shard_cache(ctx: TPContext, cache):
    """Commit a freshly initialized paged cache tree to its head-sharded
    layout."""
    return _device_put_tree(ctx.mesh, cache,
                            cache_specs(cache, ctx.axis, ctx.tp))


def localize_quantized(params):
    """Rewrite ``QuantizedLinear.shape`` metadata to the *local* (K, N)
    implied by each shard's ``qweight``: the logical shape is static
    metadata, so shard_map hands the body global numbers over local arrays
    and ``kops.gptq_linear``'s ``k, n = ql.shape`` reshape would be wrong
    without this.  ``shape[-2] * 8`` survives group-stacked leaves (the
    leading count dim slices off before the kernel sees it)."""

    def fix(ql):
        if not isinstance(ql, QuantizedLinear):
            return ql
        return dataclasses.replace(
            ql, shape=(ql.qweight.shape[-2] * 8, ql.qweight.shape[-1]))

    return jax.tree_util.tree_map(
        fix, params, is_leaf=lambda x: isinstance(x, QuantizedLinear))


# ------------------------------------------------------------- engine entry
def tp_wrap_fused(ctx: TPContext, kernels, impl):
    """shard_map wrapper for ``Engine._fused_step_impl`` — the *one* jitted
    program tensor-parallel serving wraps (ISSUE 10; the old
    decode/prefill-paged wrapper pair collapsed into this, which is also
    what lifted the spec-under-TP config rejection: verify is just another
    chunk row now).  Params/cache arrive sharded; every host-side operand
    (tokens, chunk/draft lens, masks, sampling state, PRNG keys) is
    replicated; the packed token matrix and seq_lens leave replicated so
    the engine's one device->host transfer per step is unchanged.  Meant to
    be wrapped in ``jax.jit(..., static_argnames=("all_greedy",))`` exactly
    like the single-device partial it replaces."""
    rep = P()

    def wrapped(params, tokens, chunk_lens, drafts, draft_lens, emit, cache,
                seq_lens, block_tables, live, greedy, temps, top_ks, top_ps,
                keys, draft_probs, *, all_greedy: bool = False):
        def body(params, tokens, chunk_lens, drafts, draft_lens, emit,
                 cache, seq_lens, block_tables, live, greedy, temps,
                 top_ks, top_ps, keys, draft_probs):
            params = localize_quantized(params)
            with L.tp_epilogue(ctx.axis):
                return impl(ctx.local_model, kernels, params, tokens,
                            chunk_lens, drafts, draft_lens, emit, cache,
                            seq_lens, block_tables, live, greedy, temps,
                            top_ks, top_ps, keys, draft_probs,
                            all_greedy=all_greedy)

        cspecs = cache_specs(cache, ctx.axis, ctx.tp)
        fn = _shard_map(
            body, ctx.mesh,
            in_specs=(ctx.param_specs, rep, rep, rep, rep, rep, cspecs,
                      rep, rep, rep, rep, rep, rep, rep, rep, rep),
            out_specs=(rep, cspecs, rep))
        return fn(params, tokens, chunk_lens, drafts, draft_lens, emit,
                  cache, seq_lens, block_tables, live, greedy, temps,
                  top_ks, top_ps, keys, draft_probs)

    return wrapped


def mesh_size(mesh_shape) -> int:
    """Total device count of an ``EngineConfig.mesh_shape`` (1 for None)."""
    if mesh_shape is None:
        return 1
    return math.prod(int(d) for d in mesh_shape)
