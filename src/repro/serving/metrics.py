"""Typed serving metrics registry (DESIGN.md §15).

The measurement substrate for the serving stack: a small, dependency-free
Prometheus-style registry — ``Counter`` / ``Gauge`` / ``Histogram`` families
with label sets and explicit bucket boundaries — that replaces the ad-hoc
attribute counting ``EngineStats`` used to do.  ``EngineStats``
(``serving/engine.py``) is now a thin read-view over this registry, so
existing callers and the BENCH_serving.json schema keep working unchanged.

Three consumers share one registry per engine:

* ``GET /metrics`` (``serving/http_api.py``) serves ``expose()`` — the
  Prometheus text exposition format 0.0.4, parseable back with
  ``parse_prometheus_text`` (tests + the CI gate round-trip it).
* ``benchmarks/bench_serving.py`` derives its ttft/tpot/latency percentiles
  from the histogram buckets (``Histogram.quantile`` /
  ``quantile_over``) instead of private per-request lists, and records
  ``snapshot()`` into BENCH_serving.json.
* The tracer (``serving/tracing.py``) annotates step spans with gauge
  snapshots (page-pool occupancy, queue depth).

Everything is plain host-side Python — observing a metric never touches a
device array, so the jitted hot path (one device->host transfer per decode
step) is unchanged whether metrics are on or off.  ``NULL_REGISTRY`` is the
opt-out: same API, every operation a no-op.

Timestamps never live here: latency *values* are observed into histograms
by the engine, which reads its injectable clock (``serving/clock.py``) —
this module is gated by ``tests/test_lint.py`` against direct ``time.*``
calls like every other serving module.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional

# ---------------------------------------------------------------------------
# Bucket boundaries (seconds).  Chosen for the serving regime this repo
# measures: interpret-mode CPU steps are O(100ms..s), ManualClock overload
# simulations advance in whole simulated seconds, and real-backend decode
# steps land in the low-ms bins.  The +Inf bucket is implicit.
TTFT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 4.0,
                8.0, 16.0, 32.0, 64.0)
TPOT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                1.0, 2.0, 4.0)
QUEUE_WAIT_BUCKETS = TTFT_BUCKETS
LATENCY_BUCKETS = (0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0,
                   16.0, 32.0, 64.0, 128.0)
STEP_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                0.25, 0.5, 1.0, 2.0, 4.0)
# accepted-draft-prefix length per verify step (DESIGN.md §16): small-integer
# buckets up to the SpecConfig.k ceiling of 16
SPEC_ACCEPT_BUCKETS = (0, 1, 2, 3, 4, 5, 6, 8, 12, 16)

_INF = float("inf")


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting: integers without the trailing
    ``.0`` (counters stay exact), +Inf spelled the Prometheus way."""
    if v == _INF:
        return "+Inf"
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace("\n", "\\n").replace(
        '"', '\\"')


def _labels_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


# ------------------------------------------------------------------- children
class Counter:
    """Monotone counter child (one label set of a family)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        self.value += n


class Gauge:
    """Point-in-time value child."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def inc(self, n: float = 1.0):
        self.value += n

    def dec(self, n: float = 1.0):
        self.value -= n

    def set_max(self, v: float):
        """Ratchet: keep the running peak (e.g. deepest batch admitted)."""
        if v > self.value:
            self.value = float(v)


class Histogram:
    """Cumulative-bucket histogram child with explicit upper bounds.

    ``counts[i]`` is *non*-cumulative (observations landing in bucket i);
    the exposition and ``quantile`` cumulate on the fly.  The implicit
    +Inf bucket is ``counts[-1]``.
    """
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"bucket bounds must be ascending, got {bounds}")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def quantile(self, q: float) -> float:
        return quantile_over([self], q)


def quantile_over(hists: Iterable[Histogram], q: float) -> float:
    """Prometheus-style ``histogram_quantile`` over one or more children of
    the same family (bucket layouts must match): find the bucket holding the
    q-th observation and linearly interpolate within its bounds.  The +Inf
    bucket degrades to its lower bound; an empty histogram is 0.0."""
    hists = list(hists)
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    if not hists:
        return 0.0
    bounds = hists[0].bounds
    counts = [0] * (len(bounds) + 1)
    for h in hists:
        if h.bounds != bounds:
            raise ValueError("cannot aggregate histograms with different "
                             f"bounds: {h.bounds} vs {bounds}")
        for i, c in enumerate(h.counts):
            counts[i] += c
    total = sum(counts)
    if total == 0:
        return 0.0
    rank = q * total
    cum = 0.0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        lo = bounds[i - 1] if i > 0 else 0.0
        if i == len(bounds):            # +Inf bucket: no upper bound to
            return lo                   # interpolate toward
        hi = bounds[i]
        if cum + c >= rank:
            return lo + (hi - lo) * max(0.0, rank - cum) / c
        cum += c
    return bounds[-1]


# -------------------------------------------------------------------- families
_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric with a fixed label schema and per-label-set
    children.  ``labels(k=v, ...)`` returns (creating on first use) the
    child for that label combination; zero-label families proxy the metric
    methods straight through, so ``reg.counter("x", "...").inc()`` works."""

    def __init__(self, name: str, help: str, kind: str,
                 label_names: tuple = (), buckets: Optional[tuple] = None):
        if kind not in _TYPES:
            raise ValueError(f"unknown metric type {kind!r}")
        if kind == "histogram" and buckets is None:
            raise ValueError(f"histogram {name!r} needs explicit buckets")
        if kind != "histogram" and buckets is not None:
            raise ValueError(f"buckets only apply to histograms ({name!r})")
        self.name = name
        self.help = help
        self.kind = kind
        self.label_names = tuple(label_names)
        self.buckets = buckets
        self._children: dict[tuple, object] = {}
        if not self.label_names:
            self.labels()               # eager default child: always exposed

    def labels(self, **kv):
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, got "
                f"{tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = (Histogram(self.buckets) if self.kind == "histogram"
                     else _TYPES[self.kind]())
            self._children[key] = child
        return child

    def children(self) -> list[tuple[dict, object]]:
        """(labels dict, child) pairs in first-use order (deterministic)."""
        return [(dict(zip(self.label_names, key)), c)
                for key, c in self._children.items()]

    # zero-label conveniences -------------------------------------------------
    def _default(self):
        if self.label_names:
            raise ValueError(f"{self.name} is labeled "
                             f"{self.label_names}; call .labels(...)")
        return self._children[()]

    def inc(self, n: float = 1.0):
        self._default().inc(n)

    def dec(self, n: float = 1.0):
        self._default().dec(n)

    def set(self, v: float):
        self._default().set(v)

    def set_max(self, v: float):
        self._default().set_max(v)

    def observe(self, v: float):
        self._default().observe(v)

    def quantile(self, q: float) -> float:
        """Quantile over ALL children (aggregate across label sets)."""
        return quantile_over(
            [c for _, c in self.children()], q)

    @property
    def value(self) -> float:
        """Total across children (counter/gauge read path)."""
        return sum(c.value for _, c in self.children())

    @property
    def total_count(self) -> int:
        return sum(c.count for _, c in self.children())

    @property
    def total_sum(self) -> float:
        return sum(c.sum for _, c in self.children())


class MetricsRegistry:
    """Ordered collection of metric families with optional constant labels
    (attached to every sample — the engine stamps ``layout`` and
    ``kv_quant`` here so one scrape distinguishes engines)."""

    def __init__(self, const_labels: Optional[dict] = None):
        self.const_labels = dict(const_labels or {})
        self._families: dict[str, Family] = {}

    # ------------------------------------------------------------ registration
    def _register(self, name: str, help: str, kind: str, labels: tuple,
                  buckets: Optional[tuple]) -> Family:
        fam = self._families.get(name)
        if fam is not None:
            if (fam.kind, fam.label_names, fam.buckets) != (
                    kind, tuple(labels), buckets):
                raise ValueError(
                    f"metric {name!r} re-registered with a different "
                    f"type/labels/buckets")
            return fam
        fam = Family(name, help, kind, labels, buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str, labels: tuple = ()) -> Family:
        return self._register(name, help, "counter", labels, None)

    def gauge(self, name: str, help: str, labels: tuple = ()) -> Family:
        return self._register(name, help, "gauge", labels, None)

    def histogram(self, name: str, help: str, buckets: tuple,
                  labels: tuple = ()) -> Family:
        return self._register(name, help, "histogram", labels,
                              tuple(float(b) for b in buckets))

    def get(self, name: str) -> Family:
        return self._families[name]

    def __contains__(self, name: str) -> bool:
        return name in self._families

    def families(self) -> list[Family]:
        return list(self._families.values())

    # -------------------------------------------------------------- exposition
    def expose(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        out: list[str] = []
        for fam in self._families.values():
            out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for labels, child in fam.children():
                lab = {**self.const_labels, **labels}
                if fam.kind == "histogram":
                    cum = 0
                    for i, b in enumerate(child.bounds + (_INF,)):
                        cum += child.counts[i]
                        bl = {**lab, "le": _fmt(b)}
                        out.append(f"{fam.name}_bucket{_labels_str(bl)} "
                                   f"{cum}")
                    out.append(
                        f"{fam.name}_sum{_labels_str(lab)} {_fmt(child.sum)}")
                    out.append(
                        f"{fam.name}_count{_labels_str(lab)} {child.count}")
                else:
                    out.append(
                        f"{fam.name}{_labels_str(lab)} {_fmt(child.value)}")
        return "\n".join(out) + "\n"

    # ---------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """JSON-able dump for BENCH records: every family, every label set,
        histograms with their raw (non-cumulative) bucket counts."""
        snap: dict = {"const_labels": dict(self.const_labels), "families": {}}
        for fam in self._families.values():
            series = []
            for labels, child in fam.children():
                if fam.kind == "histogram":
                    series.append({"labels": labels,
                                   "buckets": list(child.bounds),
                                   "counts": list(child.counts),
                                   "sum": child.sum, "count": child.count})
                else:
                    series.append({"labels": labels, "value": child.value})
            snap["families"][fam.name] = {
                "type": fam.kind, "help": fam.help, "series": series}
        return snap


# ------------------------------------------------------------------- opt-out
class _NullChild:
    """Absorbs every metric operation; reads as empty/zero."""
    value = 0.0
    count = 0
    sum = 0.0
    bounds: tuple = ()
    counts: list = []

    def inc(self, n=1.0):
        pass

    dec = set = set_max = observe = inc

    def quantile(self, q):
        return 0.0


class _NullFamily(_NullChild):
    total_count = 0
    total_sum = 0.0

    def labels(self, **kv):
        return self

    def children(self):
        return []


class NullRegistry(MetricsRegistry):
    """The metrics opt-out (``EngineConfig(metrics=False)``): identical API,
    nothing recorded, empty exposition — so engine code never branches."""

    def __init__(self):
        super().__init__()
        self._null = _NullFamily()

    def _register(self, name, help, kind, labels, buckets):
        return self._null

    def get(self, name):
        return self._null

    def expose(self) -> str:
        return ""

    def snapshot(self) -> dict:
        return {"const_labels": {}, "families": {}}


NULL_REGISTRY = NullRegistry()


# --------------------------------------------------------------- text parsing
def parse_prometheus_text(text: str) -> dict:
    """Parse the exposition format back into
    ``{family: {"type": t, "samples": [(sample_name, labels, value)]}}``
    (histogram ``_bucket``/``_sum``/``_count`` samples land under their base
    family) — the round-trip check tests and the CI gate run over
    ``GET /metrics`` output.  Raises
    ``ValueError`` on malformed lines, unknown types, or samples that never
    saw a TYPE header (close enough to a promtool check for a stdlib-only
    repo)."""
    metrics: dict = {}
    typed: dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4 or parts[3] not in _TYPES:
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            typed[parts[2]] = parts[3]
            metrics[parts[2]] = {"type": parts[3], "samples": []}
            continue
        if line.startswith("#"):
            continue
        # sample: name{labels} value
        brace = line.find("{")
        labels: dict[str, str] = {}
        if brace != -1:
            close = line.rfind("}")
            if close == -1:
                raise ValueError(f"line {lineno}: unclosed labels: {line!r}")
            name, rest = line[:brace], line[close + 1:]
            body = line[brace + 1:close]
            for item in filter(None, body.split(",")):
                if "=" not in item:
                    raise ValueError(
                        f"line {lineno}: malformed label {item!r}")
                k, v = item.split("=", 1)
                if not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(
                        f"line {lineno}: unquoted label value {item!r}")
                labels[k.strip()] = v[1:-1].replace('\\"', '"').replace(
                    "\\n", "\n").replace("\\\\", "\\")
        else:
            name, _, rest = line.partition(" ")
        name, rest = name.strip(), rest.strip()
        try:
            value = float(rest)
        except ValueError:
            raise ValueError(f"line {lineno}: bad sample value {rest!r}")
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            root = name[:-len(suffix)] if name.endswith(suffix) else None
            if root and typed.get(root) == "histogram":
                base = root
                break
        if base not in typed:
            raise ValueError(
                f"line {lineno}: sample {name!r} without a TYPE header")
        if math.isnan(value):
            raise ValueError(f"line {lineno}: NaN sample value")
        metrics[base]["samples"].append((name, labels, value))
    return metrics


# --------------------------------------------------------- the engine catalog
class EngineMetrics:
    """The serving metric catalog (DESIGN.md §15), bound to one registry.

    One instance per ``Engine``; attribute access is the hot-path-cheap
    handle the engine increments.  ``layout`` / ``kv_quant`` become constant
    labels so scrapes from different engine configs stay distinguishable.
    """

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        r = registry
        # counters ----------------------------------------------------------
        self.tokens_generated = r.counter(
            "engine_tokens_generated_total", "Decode tokens sampled")
        self.prefill_tokens = r.counter(
            "engine_prefill_tokens_total", "Prompt tokens prefilled")
        self.steps = r.counter(
            "engine_steps_total", "Engine.step() iterations")
        self.wall_seconds = r.counter(
            "engine_wall_seconds_total",
            "Clock seconds spent inside Engine.step (injectable clock)")
        self.prefix_hit_pages = r.counter(
            "engine_prefix_hit_pages_total",
            "KV pages served from the hashed-prefix cache")
        self.prefix_hit_tokens = r.counter(
            "engine_prefix_hit_tokens_total",
            "Prompt tokens skipped via prefix-cache hits")
        self.preemptions = r.counter(
            "engine_preemptions_total",
            "Victims evicted for higher-priority admissions")
        self.offloaded_pages = r.counter(
            "engine_offloaded_pages_total",
            "Pages checkpointed to host memory by preemption")
        self.offloaded_bytes = r.counter(
            "engine_offloaded_bytes_total",
            "Host bytes of preemption checkpoints")
        self.restored_pages = r.counter(
            "engine_restored_pages_total",
            "Checkpointed pages scattered back on-device")
        self.rejected_submits = r.counter(
            "engine_rejected_submits_total",
            "submit() refused at max_queued (HTTP 429)")
        self.deferred_admissions = r.counter(
            "engine_deferred_admissions_total",
            "Head-of-queue reservation failures (admission deferred)")
        self.shed_requests = r.counter(
            "engine_shed_requests_total",
            "Requests shed past their queue deadline (HTTP 503)")
        self.requests_finished = r.counter(
            "engine_requests_finished_total",
            "Requests leaving the engine, by finish reason",
            labels=("reason",))
        self.faults_injected = r.counter(
            "engine_faults_injected_total",
            "FaultInjector events fired, by kind", labels=("kind",))
        # speculative decoding (DESIGN.md §16) ------------------------------
        self.spec_proposed = r.counter(
            "engine_spec_proposed_total",
            "Draft tokens proposed by the speculator")
        self.spec_accepted = r.counter(
            "engine_spec_accepted_total",
            "Draft tokens accepted by the verify pass")
        self.spec_verify_steps = r.counter(
            "engine_spec_verify_steps_total",
            "Speculative verify steps executed")
        # gauges ------------------------------------------------------------
        self.active_requests = r.gauge(
            "engine_active_requests", "Requests currently decoding")
        self.waiting_requests = r.gauge(
            "engine_waiting_requests", "Requests queued for admission")
        self.peak_active = r.gauge(
            "engine_peak_active", "Deepest concurrent batch ever admitted")
        self.page_pool_pages = r.gauge(
            "engine_page_pool_pages", "Allocatable pages in the paged pool")
        self.page_pool_free = r.gauge(
            "engine_page_pool_free_pages", "Pages on the paged free list")
        self.page_pool_utilization = r.gauge(
            "engine_page_pool_utilization",
            "Fraction of the page pool allocated")
        self.offloaded_bytes_current = r.gauge(
            "engine_offloaded_bytes_current",
            "Host bytes currently held by preemption checkpoints")
        # per-device pool gauges (DESIGN.md §17): under tensor parallelism
        # every device owns the num_kv_heads/tp head-slice of the same
        # global page ids, so /metrics exposes pool skew (or, today, its
        # absence — the replicated free list keeps the shards in lockstep)
        # per shard instead of one aggregate
        self.page_pool_device_free = r.gauge(
            "engine_page_pool_device_free_pages",
            "Pages on the free list, by mesh device", labels=("device",))
        self.page_pool_device_bytes = r.gauge(
            "engine_page_pool_device_bytes",
            "Page-pool bytes resident on one mesh device (head-slice of "
            "payload + scale pools)", labels=("device",))
        self.offloaded_bytes_device = r.gauge(
            "engine_offloaded_bytes_device",
            "Host checkpoint bytes attributable to one mesh device's "
            "head-slice", labels=("device",))
        self._devices = 1
        self._device_pool_bytes = 0
        # histograms (explicit buckets, DESIGN.md §15) ----------------------
        self.ttft = r.histogram(
            "engine_ttft_seconds", "Time to first token, by priority class",
            TTFT_BUCKETS, labels=("priority",))
        self.tpot = r.histogram(
            "engine_tpot_seconds",
            "Per-output-token decode time (post-first-token)", TPOT_BUCKETS)
        self.queue_wait = r.histogram(
            "engine_queue_wait_seconds",
            "Submit-to-admission wait", QUEUE_WAIT_BUCKETS)
        self.request_latency = r.histogram(
            "engine_request_latency_seconds",
            "End-to-end request latency", LATENCY_BUCKETS)
        self.step_duration = r.histogram(
            "engine_step_duration_seconds",
            "Engine.step() duration (injectable clock)", STEP_BUCKETS)
        self.spec_accept_len = r.histogram(
            "engine_spec_accept_length",
            "Accepted-draft-prefix length per request per verify step",
            SPEC_ACCEPT_BUCKETS)

    def configure_devices(self, n: int, pool_bytes_per_device: int) -> None:
        """Declare the mesh size (1 on a single device) and each device's
        resident pool bytes so ``sync_pool`` can fan the occupancy out to
        the device-labeled gauges.  Called once at engine construction."""
        self._devices = max(1, int(n))
        self._device_pool_bytes = int(pool_bytes_per_device)

    def sync_pool(self, pc) -> None:
        """Refresh the page-pool occupancy/offload gauges from a
        ``PagedCache`` (``occupancy()``) — called once per step."""
        occ = pc.occupancy()
        self.page_pool_pages.set(occ["num_pages"])
        self.page_pool_free.set(occ["free_pages"])
        self.page_pool_utilization.set(occ["utilization"])
        self.offloaded_bytes_current.set(occ["offloaded_bytes"])
        # per-device fan-out: the free list is replicated bookkeeping (page
        # ids are global, every device holds its head-slice of every page),
        # and a checkpointed page's host bytes split evenly across shards
        for d in range(self._devices):
            self.page_pool_device_free.labels(device=d).set(
                occ["free_pages"])
            self.page_pool_device_bytes.labels(device=d).set(
                self._device_pool_bytes)
            self.offloaded_bytes_device.labels(device=d).set(
                occ["offloaded_bytes"] / self._devices)


def make_engine_metrics(layout: str, kv_quant: str,
                        enabled: bool = True) -> EngineMetrics:
    """Registry + catalog for one engine.  ``enabled=False`` binds the
    catalog to ``NULL_REGISTRY`` — every observation is a no-op and
    ``expose()`` is empty, the documented opt-out."""
    if not enabled:
        return EngineMetrics(NullRegistry())
    return EngineMetrics(MetricsRegistry(
        const_labels={"layout": layout, "kv_quant": kv_quant}))
