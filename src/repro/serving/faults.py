"""Deterministic serving fault injection (DESIGN.md §14).

A ``FaultInjector`` is handed to the engine via ``EngineConfig.faults``;
``Engine.step()`` calls ``on_step(engine)`` once at the top of every
iteration (before admissions), so every injected fault lands at a
reproducible point in the request schedule:

* **page-pool exhaustion** — ``exhaust_pages_at(step, n)`` seizes ``n``
  pages from the paged free list (refcounted like a live sequence, so
  nothing else can allocate them) and ``release_pages_at(step)`` gives
  them back.  This is how tests and ``bench_serving.py`` force admission
  deferral and preemption without building giant workloads.
* **step-time stalls** — ``stall_at(step, fn)`` runs ``fn`` at that step;
  with a ``ManualClock`` the canonical ``fn`` advances the clock past the
  worker watchdog timeout (no real sleeping), driving stall detection
  deterministically.
* **mid-stream aborts** — ``abort_at(step, rid)`` cancels a request while
  it is decoding, exactly like a client disconnect at that instant.

The injector also works standalone against a ``PagedCache`` via
``seize_pages``/``release_seized`` for unit tests that bypass the engine.
"""
from __future__ import annotations

from typing import Callable


class FaultInjector:
    """Schedules faults by engine step number (0-based, counted across
    ``Engine.step()`` calls).  One injector drives one engine."""

    def __init__(self):
        self.step_no = 0
        self._stalls: dict[int, Callable[[], None]] = {}
        self._aborts: dict[int, list[int]] = {}
        self._exhaust: dict[int, int] = {}
        self._release_at: set[int] = set()
        self._seized: list[int] = []
        self._seized_pc = None
        # (step, kind, detail) record of every fault that actually fired
        self.log: list[tuple[int, str, object]] = []

    # ------------------------------------------------------------- scheduling
    def stall_at(self, step: int, fn: Callable[[], None]) -> "FaultInjector":
        self._stalls[step] = fn
        return self

    def abort_at(self, step: int, rid: int) -> "FaultInjector":
        self._aborts.setdefault(step, []).append(rid)
        return self

    def exhaust_pages_at(self, step: int, n: int) -> "FaultInjector":
        self._exhaust[step] = n
        return self

    def release_pages_at(self, step: int) -> "FaultInjector":
        self._release_at.add(step)
        return self

    # ------------------------------------------------------- page pool faults
    def seize_pages(self, pc, n: int) -> int:
        """Take up to ``n`` pages out of the free list, refcounted so they
        look allocated to every admission/reservation path.  Returns how
        many were actually seized (the free list may be shorter)."""
        if self._seized and self._seized_pc is not pc:
            raise RuntimeError("injector already holds pages of another pool")
        taken = 0
        while taken < n and pc.free_list:
            p = pc.free_list.pop()
            pc.refcount[p] += 1
            self._seized.append(p)
            taken += 1
        self._seized_pc = pc if self._seized else None
        return taken

    def release_seized(self, pc=None) -> int:
        """Return every seized page to its pool's free list."""
        pc = pc if pc is not None else self._seized_pc
        released = 0
        while self._seized:
            p = self._seized.pop()
            pc.refcount[p] -= 1
            if pc.refcount[p] == 0:
                pc.free_list.append(p)
                released += 1
        self._seized_pc = None
        return released

    @property
    def seized_pages(self) -> int:
        return len(self._seized)

    # ------------------------------------------------------------ engine hook
    def on_step(self, engine) -> None:
        """Called by ``Engine.step()`` before admissions; fires every fault
        scheduled for the current step number.  Each fired fault is recorded
        in ``self.log`` and surfaced to the engine's observability layer:
        an ``engine_faults_injected_total{kind=...}`` increment and a trace
        instant on the engine track (DESIGN.md §15)."""
        s = self.step_no
        self.step_no += 1
        for rid in self._aborts.pop(s, []):
            # the RequestOutput lands in the log (abort() returns it to its
            # caller, not through step()'s finished list)
            self.log.append((s, "abort", engine.abort(rid)))
            self._observe(engine, "abort", rid=rid)
        n = self._exhaust.pop(s, None)
        if n is not None:
            got = self.seize_pages(engine.pc, n)
            self.log.append((s, "exhaust_pages", got))
            self._observe(engine, "exhaust_pages", pages=got)
        if s in self._release_at:
            self._release_at.discard(s)
            got = self.release_seized(engine.pc)
            self.log.append((s, "release_pages", got))
            self._observe(engine, "release_pages", pages=got)
        fn = self._stalls.pop(s, None)
        if fn is not None:
            fn()
            self.log.append((s, "stall", None))
            self._observe(engine, "stall")

    def _observe(self, engine, kind: str, **detail) -> None:
        metrics = getattr(engine, "metrics", None)
        if metrics is not None:
            metrics.faults_injected.labels(kind=kind).inc()
        tracer = getattr(engine, "tracer", None)
        if tracer is not None:
            tracer.fault_instant(kind, engine.clock.now(),
                                 step=self.step_no - 1, **detail)


def clock_stall(clock, dt: float) -> Callable[[], None]:
    """A stall action for ``stall_at``: advance a ``ManualClock`` by ``dt``
    seconds — the deterministic stand-in for a step that took that long."""
    def _advance():
        clock.advance(dt)
    return _advance
