"""Serving cache managers.

Two layouts (DESIGN.md §2 — hardware adaptation of vLLM's PagedAttention):

* ``SlotCache`` — TPU path: the model's native slot-based contiguous cache
  (fixed max_len per decode slot). Slot allocation/free is O(1); the jitted
  decode step is shape-stable. This is what JetStream-style TPU serving does
  instead of paging.

* ``PagedCache`` — CPU-engine option faithful to the paper's vLLM substrate:
  block tables mapping logical token blocks to a shared physical page pool,
  with copy-free sharing of common prefixes and page-level free lists.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


class SlotCache:
    """Fixed-slot cache wrapper around the model's init_cache tree."""

    def __init__(self, model, batch_slots: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.model = model
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len, dtype=dtype)
        self.seq_lens = jnp.zeros((batch_slots,), jnp.int32)
        self._free = list(range(batch_slots))[::-1]
        self._live: set[int] = set()

    def alloc(self) -> int | None:
        if not self._free:
            return None
        slot = self._free.pop()
        self._live.add(slot)
        return slot

    def free(self, slot: int):
        self._live.discard(slot)
        self._free.append(slot)
        # zero this slot's length so masks exclude stale entries
        self.seq_lens = self.seq_lens.at[slot].set(0)

    @property
    def num_free(self) -> int:
        return len(self._free)


@dataclasses.dataclass
class PagedCache:
    """Block-table KV pool (numpy bookkeeping; pages are jnp arrays).

    pages[layer]: (num_pages, page_size, Hkv, D) x2 (k, v)
    block_table : seq_id -> list of page ids (+ ref counts for prefix sharing)
    """
    num_pages: int
    page_size: int
    n_layers: int
    kv_heads: int
    head_dim: int
    dtype: object = jnp.bfloat16

    def __post_init__(self):
        shape = (self.n_layers, self.num_pages, self.page_size,
                 self.kv_heads, self.head_dim)
        self.k_pages = jnp.zeros(shape, self.dtype)
        self.v_pages = jnp.zeros(shape, self.dtype)
        self.free_list = list(range(self.num_pages))[::-1]
        self.tables: dict[int, list[int]] = {}
        self.lengths: dict[int, int] = {}
        self.refcount = np.zeros(self.num_pages, np.int32)

    # ------------------------------------------------------------ bookkeeping
    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return len(self.free_list) >= self.pages_needed(n_tokens)

    def alloc_seq(self, seq_id: int, n_tokens: int,
                  share_from: int | None = None) -> bool:
        """Allocate pages for a sequence; optionally share a common prefix
        (copy-on-write refcounting, the PagedAttention trick)."""
        pages: list[int] = []
        shared = 0
        if share_from is not None and share_from in self.tables:
            src = self.tables[share_from]
            shared = min(len(src), n_tokens // self.page_size)
            for p in src[:shared]:
                self.refcount[p] += 1
                pages.append(p)
        need = self.pages_needed(n_tokens) - shared
        if len(self.free_list) < need:
            for p in pages:
                self.refcount[p] -= 1
            return False
        for _ in range(need):
            p = self.free_list.pop()
            self.refcount[p] += 1
            pages.append(p)
        self.tables[seq_id] = pages
        self.lengths[seq_id] = n_tokens
        return True

    def extend_seq(self, seq_id: int, n_new: int = 1) -> bool:
        length = self.lengths[seq_id] + n_new
        need = self.pages_needed(length) - len(self.tables[seq_id])
        if need > 0:
            if len(self.free_list) < need:
                return False
            for _ in range(need):
                p = self.free_list.pop()
                self.refcount[p] += 1
                self.tables[seq_id].append(p)
        self.lengths[seq_id] = length
        return True

    def free_seq(self, seq_id: int):
        for p in self.tables.pop(seq_id, []):
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.free_list.append(p)
        self.lengths.pop(seq_id, None)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free_list) / self.num_pages

    # -------------------------------------------------------------- data path
    def write_tokens(self, seq_id: int, layer: int, start: int,
                     k: jnp.ndarray, v: jnp.ndarray):
        """k, v: (n, Hkv, D) written at logical positions [start, start+n)."""
        table = self.tables[seq_id]
        n = k.shape[0]
        for i in range(n):
            pos = start + i
            page = table[pos // self.page_size]
            off = pos % self.page_size
            self.k_pages = self.k_pages.at[layer, page, off].set(
                k[i].astype(self.dtype))
            self.v_pages = self.v_pages.at[layer, page, off].set(
                v[i].astype(self.dtype))

    def gather_kv(self, seq_id: int, layer: int):
        """Returns (k, v): (len, Hkv, D) gathered via the block table."""
        table = jnp.asarray(self.tables[seq_id], jnp.int32)
        length = self.lengths[seq_id]
        k = self.k_pages[layer, table].reshape(-1, self.kv_heads, self.head_dim)
        v = self.v_pages[layer, table].reshape(-1, self.kv_heads, self.head_dim)
        return k[:length], v[:length]
