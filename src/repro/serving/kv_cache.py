"""Serving cache managers.

Two layouts (DESIGN.md §2, §10 — hardware adaptation of vLLM's
PagedAttention):

* ``SlotCache`` — the model's native slot-based contiguous cache (fixed
  max_len per decode slot). Slot allocation/free is O(1); the jitted decode
  step is shape-stable. This is what JetStream-style TPU serving does
  instead of paging, and it remains the engine default.

* ``PagedCache`` — device-resident block-table KV pool: fixed-size physical
  pages shared across sequences, a ``(max_seqs, max_pages)`` int32 device
  block table consumed directly by the Pallas paged-attention decode kernel
  (``kernels/paged_attention.py``), refcounted free lists with
  copy-on-write on shared-page writes, and a hashed-prefix cache that
  reuses full pages across requests with identical prompt prefixes.

Physical page 0 is the **null page**: never allocated, permanently
refcounted, the target of block-table padding and of dead decode rows'
writes.  ``num_pages`` counts *allocatable* pages, so pool arrays hold
``num_pages + 1`` physical pages.

Both layouts take a ``kv_quant`` (``serving/kv_quant.py::KVQuantConfig``):
int8 payloads with parallel symmetric-scale pools, quantize-on-write /
dequantize-on-read fused into every data-path method (DESIGN.md §12).
``PagedCache`` supports per-token *and* per-page scale granularity; scale
pools ride along with their pages through copy-on-write and prefix sharing.

Overload resilience (DESIGN.md §14): ``offload(seq_id)`` checkpoints a
sequence's private pages to host memory and releases everything it holds
(shared prefix pages are *released, not copied* — their payload stays live
on device under the donor's refcount); ``restore(seq_id)`` re-allocates
through the normal admission path (prefix-cache hits included) and scatters
the host snapshot back.  Payload movement is pluggable (``gather``/
``scatter`` callables) because the engine keeps page payloads in its model
cache tree (``alloc_pools=False``); with ``alloc_pools=True`` the cache
moves its own pools.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kv_quant as KQ

# The single source of the serving cache dtype: SlotCache, PagedCache and
# Engine all default to this (the seed had SlotCache default to bfloat16
# while Engine passed float32 — two defaults, one of them dead).
DEFAULT_CACHE_DTYPE = jnp.float32

NULL_PAGE = 0


def prefix_hash_seed(quant_tag: tuple, page_size: int) -> int:
    """Deterministic seed for the hashed-prefix chain, derived from the KV
    quant mode + page size via sha256 — NOT Python's ``hash()``, whose
    string hashing is randomized per process (PYTHONHASHSEED).  The rest of
    the chain (``hash((int_key, int_tuple))``) only ever hashes integers,
    which Python hashes deterministically, so a deterministic seed makes
    the whole chain stable across processes — the property that lets a
    persisted prefix index (DESIGN.md §16) be reloaded by a fresh engine."""
    blob = repr(("kv_prefix_seed_v1", page_size) + tuple(quant_tag))
    digest = hashlib.sha256(blob.encode("utf-8")).digest()
    return int.from_bytes(digest[:8], "big", signed=True)


class SlotCache:
    """Fixed-slot cache wrapper around the model's init_cache tree."""

    def __init__(self, model, batch_slots: int, max_len: int,
                 dtype=DEFAULT_CACHE_DTYPE, kv_quant=None):
        self.model = model
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.kv_quant = kv_quant
        quantized = kv_quant is not None and kv_quant.quantized
        self.dtype = jnp.dtype(jnp.int8) if quantized else jnp.dtype(dtype)
        self.cache = model.init_cache(batch_slots, max_len, dtype=dtype,
                                      kv_quant=kv_quant)
        self.seq_lens = jnp.zeros((batch_slots,), jnp.int32)
        self._free = list(range(batch_slots))[::-1]
        self._live: set[int] = set()

    def alloc(self) -> int | None:
        if not self._free:
            return None
        slot = self._free.pop()
        self._live.add(slot)
        return slot

    def free(self, slot: int):
        self._live.discard(slot)
        self._free.append(slot)
        # zero this slot's length so masks exclude stale entries
        self.seq_lens = self.seq_lens.at[slot].set(0)

    @property
    def num_free(self) -> int:
        return len(self._free)


@dataclasses.dataclass
class OffloadedSeq:
    """Host-memory checkpoint of a preempted sequence (DESIGN.md §14).

    ``payload`` is a host copy of the sequence's *private* pages (logical
    pages ``[shared_pages, pages_needed(length))`` — page axis 1 in every
    leaf, scale pools riding along).  The leading ``shared_pages`` full
    prefix pages were shared (refcount > 1) at offload time and were
    released without copying: their payload stays live on device under the
    donor's refcount, and restore re-finds them through the hashed-prefix
    cache — or recomputes them if the donor has since evicted."""
    seq_id: int
    length: int                 # context tokens the snapshot covers
    shared_pages: int           # leading prefix pages released, not copied
    payload: Any                # host pytree, page axis 1 (None when empty)
    n_payload_pages: int
    nbytes: int                 # host bytes held by ``payload``


@dataclasses.dataclass
class RestoredSeq:
    """What ``restore`` did, for the engine's bookkeeping: prefix pages
    re-shared from the live cache, where the host snapshot started, and the
    pages scattered back.  Logical pages ``[hit_pages, snap_start_page)``
    (non-empty only when the donor evicted while this sequence was
    offloaded) hold no data — the caller must recompute that token span."""
    hit_pages: int
    snap_start_page: int
    length: int
    restored_pages: int


@dataclasses.dataclass
class PagedCache:
    """Block-table KV pool with a device-resident block table.

    k_pages/v_pages: (n_layers, num_pages + 1, page_size, Hkv, D) pools.
    block_tables   : (max_seqs, max_pages) int32 device array; row ``r`` maps
                     sequence-in-row-r logical page ``i`` to a physical page.
    Host bookkeeping (free list, refcounts, per-seq tables, prefix hashes)
    stays in plain Python/numpy; only page payloads and the block table are
    device arrays.
    """
    num_pages: int
    page_size: int
    n_layers: int
    kv_heads: int
    head_dim: int
    dtype: object = None            # None -> DEFAULT_CACHE_DTYPE
    max_seqs: int = 0               # 0 -> num_pages (every seq needs >=1 page)
    max_pages: int = 0              # block-table width; 0 -> num_pages
    alloc_pools: bool = True        # False: bookkeeping only — the engine
                                    # stores page payloads in the model cache
                                    # tree (init_paged_cache), not here
    kv_quant: object = None         # KVQuantConfig: int8 pools + scale pools

    def __post_init__(self):
        # compute_dtype: what gather_kv returns; dtype: what the pools store
        self.compute_dtype = jnp.dtype(self.dtype if self.dtype is not None
                                       else DEFAULT_CACHE_DTYPE)
        quantized = self.kv_quant is not None and self.kv_quant.quantized
        self.dtype = jnp.dtype(jnp.int8) if quantized else self.compute_dtype
        self.max_seqs = self.max_seqs or self.num_pages
        self.max_pages = self.max_pages or self.num_pages
        shape = (self.n_layers, self.num_pages + 1, self.page_size,
                 self.kv_heads, self.head_dim)
        self.k_scales = self.v_scales = None
        if self.alloc_pools:
            self.k_pages = jnp.zeros(shape, self.dtype)
            self.v_pages = jnp.zeros(shape, self.dtype)
            if quantized:
                sshape = (self.n_layers,) + KQ.paged_scale_shape(
                    self.num_pages, self.page_size, self.kv_heads,
                    self.kv_quant.granularity)
                sdt = self.kv_quant.scale_jnp_dtype
                self.k_scales = jnp.zeros(sshape, sdt)
                self.v_scales = jnp.zeros(sshape, sdt)
        else:
            self.k_pages = self.v_pages = None
        self.seq_lens = jnp.zeros((self.max_seqs,), jnp.int32)
        # pop() order 1, 2, 3, ...; page 0 is the never-allocated null page
        self.free_list = list(range(self.num_pages, 0, -1))
        self.tables: dict[int, list[int]] = {}
        self.lengths: dict[int, int] = {}
        self.refcount = np.zeros(self.num_pages + 1, np.int32)
        self.refcount[NULL_PAGE] = np.iinfo(np.int32).max // 2   # pinned
        self.block_tables = jnp.zeros((self.max_seqs, self.max_pages),
                                      jnp.int32)
        self.rows: dict[int, int] = {}
        self._free_rows = list(range(self.max_seqs))[::-1]
        # hashed-prefix cache: chain-hash of page-aligned token prefixes.
        # The chain is seeded with the KV quant mode (ISSUE 6 satellite /
        # ROADMAP carry-over): pages written under one quant config can
        # never be served to a lookup under another — int8 payloads+scales
        # and bf16 payloads for the same tokens are different bytes, so
        # their keys must differ once prefix indexes outlive one cache
        # instance (persisted prefix caches, engine restarts).
        quant_tag = ((self.kv_quant.dtype, self.kv_quant.granularity)
                     if quantized else ("fp", str(self.compute_dtype)))
        self._hash_seed = prefix_hash_seed(quant_tag, self.page_size)
        self._prefix_index: dict[int, int] = {}      # hash key -> page id
        self._page_key: dict[int, int] = {}          # page id -> hash key
        self.prefix_hits: dict[int, int] = {}        # seq_id -> pages reused
        # preempted sequences' host-memory page checkpoints (DESIGN.md §14)
        self.offloaded: dict[int, OffloadedSeq] = {}

    # ------------------------------------------------------------ bookkeeping
    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return len(self.free_list) >= self.pages_needed(n_tokens)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free_list) / self.num_pages

    def occupancy(self) -> dict:
        """Point-in-time pool occupancy for the observability layer
        (DESIGN.md §15): page-pool gauges and step-span annotations read
        this one snapshot instead of poking at internals."""
        return {
            "num_pages": self.num_pages,
            "free_pages": len(self.free_list),
            "utilization": self.utilization,
            "live_seqs": len(self.tables),
            "offloaded_seqs": len(self.offloaded),
            "offloaded_bytes": self.offloaded_bytes,
        }

    def row_of(self, seq_id: int) -> int:
        return self.rows[seq_id]

    def _sync_row(self, seq_id: int):
        """Push one sequence's host table into the device block table."""
        row = self.rows[seq_id]
        arr = np.full((self.max_pages,), NULL_PAGE, np.int32)
        table = self.tables[seq_id]
        arr[:len(table)] = table
        self.block_tables = self.block_tables.at[row].set(jnp.asarray(arr))

    def _prefix_keys(self, tokens) -> list[int]:
        """Chain hashes of each full-page-aligned prefix of ``tokens``,
        seeded with the KV quant mode so distinct quant configs can never
        collide on the same token prefix."""
        keys, key = [], self._hash_seed
        for i in range(len(tokens) // self.page_size):
            page = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            key = hash((key, page))
            keys.append(key)
        return keys

    def alloc_seq(self, seq_id: int, n_tokens: int,
                  share_from: int | None = None,
                  tokens=None, reserve: int = 0) -> bool:
        """Allocate pages (and a block-table row) for a sequence.

        Prefix reuse, in priority order: ``share_from`` (explicit donor —
        full pages of the donor's table are refcounted in), else ``tokens``
        (the prompt ids) consults the hashed-prefix cache.  ``reserve``
        tokens of extra page capacity are allocated up front (the engine
        reserves the decode budget at admission so generation can never hit
        pool exhaustion mid-flight).  Returns False — with no state change —
        when pages or rows are unavailable.
        """
        if seq_id in self.tables:
            raise ValueError(f"seq {seq_id} already allocated")
        pages: list[int] = []
        shared = 0
        if share_from is not None and share_from in self.tables:
            src = self.tables[share_from]
            shared = min(len(src), n_tokens // self.page_size)
            pages = src[:shared]
        elif tokens is not None:
            keys = self._prefix_keys(tokens)[:self._max_shared_pages(n_tokens)]
            for key in keys:
                page = self._prefix_index.get(key)
                if page is None or self.refcount[page] <= 0:
                    break
                pages.append(page)
            shared = len(pages)
        need = self.pages_needed(n_tokens + reserve) - shared
        if (need > len(self.free_list) or not self._free_rows
                or self.pages_needed(n_tokens + reserve) > self.max_pages):
            return False
        pages = list(pages)               # never alias a donor's table
        for p in pages:
            self.refcount[p] += 1
        for _ in range(need):
            p = self.free_list.pop()
            self.refcount[p] += 1
            pages.append(p)
        self.tables[seq_id] = pages
        self.lengths[seq_id] = n_tokens
        self.rows[seq_id] = self._free_rows.pop()
        if tokens is not None and share_from is None:
            self.prefix_hits[seq_id] = shared
        self._sync_row(seq_id)
        return True

    def _max_shared_pages(self, n_tokens: int) -> int:
        """Prefix-cache hits are capped below full-prompt coverage: at least
        one suffix token must remain, or prefill would run over zero real
        tokens and the first sampled token would come from padding logits
        (ISSUE 5).  ``Engine._admit_paged`` guards the same invariant with a
        page backoff in case a future admission path bypasses this cap."""
        return (n_tokens - 1) // self.page_size

    def release_prefix(self, seq_id: int, keep: int) -> int:
        """Drop prefix sharing beyond the first ``keep`` pages of ``seq_id``:
        every later page of its table that is still shared (refcount > 1) is
        swapped for a fresh private page, so the caller can re-prefill the
        dropped span without scribbling on a donor's live page.  The old
        payload is never copied (unlike COW) — the caller rewrites the whole
        dropped span — which is what makes this safe with
        ``alloc_pools=False``, where the payloads live in the engine's model
        cache tree.  Returns the number of pages swapped; raises when the
        free list cannot supply a replacement."""
        table = self.tables[seq_id]
        swapped = 0
        try:
            for li in range(keep, len(table)):
                p = table[li]
                if self.refcount[p] <= 1:
                    continue               # already private: rewriting is safe
                if not self.free_list:
                    raise RuntimeError(
                        "page pool exhausted while privatizing prefix pages "
                        f"of seq {seq_id} (backoff from page {keep})")
                q = self.free_list.pop()
                self.refcount[p] -= 1
                self.refcount[q] += 1
                table[li] = q
                swapped += 1
        finally:
            if swapped:
                self._sync_row(seq_id)
        return swapped

    def extend_seq(self, seq_id: int, n_new: int = 1) -> bool:
        old = self.lengths[seq_id]
        length = old + n_new
        need = self.pages_needed(length) - len(self.tables[seq_id])
        if need > 0:
            if (len(self.free_list) < need
                    or self.pages_needed(length) > self.max_pages):
                return False
            for _ in range(need):
                p = self.free_list.pop()
                self.refcount[p] += 1
                self.tables[seq_id].append(p)
            self._sync_row(seq_id)
        # growing into a shared partially-filled page must trigger COW now,
        # before any write lands at positions [old, length)
        self._ensure_writable(seq_id, old, length)
        self.lengths[seq_id] = length
        return True

    def free_seq(self, seq_id: int):
        for p in self.tables.pop(seq_id, []):
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.free_list.append(p)
                key = self._page_key.pop(p, None)
                if key is not None and self._prefix_index.get(key) == p:
                    del self._prefix_index[key]
        self.lengths.pop(seq_id, None)
        self.prefix_hits.pop(seq_id, None)
        row = self.rows.pop(seq_id, None)
        if row is not None:
            self._free_rows.append(row)
            self.block_tables = self.block_tables.at[row].set(
                jnp.zeros((self.max_pages,), jnp.int32))
            self.seq_lens = self.seq_lens.at[row].set(0)

    # ------------------------------------------------------------ prefix cache
    def register_prefix(self, seq_id: int, tokens):
        """Publish this sequence's full, written pages to the prefix cache
        (call after the prompt KV has actually been written)."""
        table = self.tables[seq_id]
        for i, key in enumerate(self._prefix_keys(tokens)):
            page = table[i]
            # page -> key stays injective: a page already published under a
            # key keeps it (re-keying would leak the old entry at eviction)
            if key not in self._prefix_index and page not in self._page_key:
                self._prefix_index[key] = page
                self._page_key[page] = key

    def export_prefix_index(self) -> tuple[list[int], list[int]]:
        """Live prefix-cache entries as parallel (keys, page_ids) lists in
        deterministic (key-sorted) order — the engine's persistence layer
        (DESIGN.md §16) serializes these alongside the page payloads."""
        items = sorted((k, p) for k, p in self._prefix_index.items()
                       if self.refcount[p] > 0)
        return [k for k, _ in items], [p for _, p in items]

    def adopt_prefix_pages(self, keys) -> list[tuple[int, int]]:
        """Re-seat a persisted prefix index: allocate one *pinned* physical
        page per key (refcount starts at 1 with no owning sequence, so the
        warm set is never evicted) and publish it under that key.  Returns
        ``(key, page_id)`` pairs for the keys actually adopted — the caller
        scatters the matching payloads there.  Keys already present or past
        the free list's capacity are skipped (a chain lookup simply stops at
        its first missing link, so partial adoption is always safe)."""
        adopted: list[tuple[int, int]] = []
        for key in keys:
            key = int(key)
            if key in self._prefix_index or not self.free_list:
                continue
            page = self.free_list.pop()
            self.refcount[page] += 1
            self._prefix_index[key] = page
            self._page_key[page] = key
            adopted.append((key, page))
        return adopted

    # ------------------------------------------------------- offload / restore
    def _gather_pages_local(self, page_ids):
        """Default payload gather for ``alloc_pools=True``: host copies of
        the named physical pages from this cache's own pools (page axis 1),
        scale pools included."""
        self._require_pools()
        idx = np.asarray(page_ids, np.int32)
        tree = {"k_pages": self.k_pages, "v_pages": self.v_pages}
        if self.k_scales is not None:
            tree.update(k_scales=self.k_scales, v_scales=self.v_scales)
        return jax.tree_util.tree_map(lambda a: np.asarray(a[:, idx]), tree)

    def _scatter_pages_local(self, page_ids, payload):
        """Default payload scatter: write host pages back into this cache's
        pools at the (freshly allocated) physical page ids."""
        self._require_pools()
        idx = jnp.asarray(page_ids, jnp.int32)
        self.k_pages = self.k_pages.at[:, idx].set(
            jnp.asarray(payload["k_pages"]))
        self.v_pages = self.v_pages.at[:, idx].set(
            jnp.asarray(payload["v_pages"]))
        if self.k_scales is not None:
            self.k_scales = self.k_scales.at[:, idx].set(
                jnp.asarray(payload["k_scales"]))
            self.v_scales = self.v_scales.at[:, idx].set(
                jnp.asarray(payload["v_scales"]))

    def offload(self, seq_id: int,
                gather: Optional[Callable] = None) -> OffloadedSeq:
        """Swap a live sequence out to host memory and release everything
        it holds on device (DESIGN.md §14).

        Refcount- and COW-correct: leading *shared* full prefix pages
        (refcount > 1) are released, never copied — their payload stays
        live under the donor's refcount and restore re-shares (or, if the
        donor evicted, recomputes) them.  Private pages covering the rest
        of ``[0, length)`` are copied to host via ``gather(page_ids)``
        (page axis 1; the engine passes a gatherer over its model cache
        tree, ``alloc_pools=True`` caches copy their own pools).  Reserve
        pages past the written extent hold no data and are just released.
        The block-table row, free list and prefix index are left exactly as
        ``free_seq`` leaves them; the checkpoint is recorded in
        ``self.offloaded`` until ``restore`` or ``drop_offloaded``.
        """
        if seq_id in self.offloaded:
            raise ValueError(f"seq {seq_id} is already offloaded")
        table = self.tables[seq_id]
        length = self.lengths[seq_id]
        used = self.pages_needed(length)
        shared = 0
        while shared < used and self.refcount[table[shared]] > 1:
            shared += 1
        for li in range(shared, used):
            # the engine only ever shares leading full prefix pages; a
            # shared page after a private one would be silently lost here
            if self.refcount[table[li]] > 1:
                raise RuntimeError(
                    f"seq {seq_id}: shared page at logical index {li} after "
                    f"private pages — offload supports leading-prefix "
                    f"sharing only")
        snap_ids = table[shared:used]
        payload = None
        nbytes = 0
        if snap_ids:
            gather = gather if gather is not None else self._gather_pages_local
            payload = gather(list(snap_ids))
            nbytes = sum(leaf.nbytes
                         for leaf in jax.tree_util.tree_leaves(payload))
        rec = OffloadedSeq(seq_id=seq_id, length=length, shared_pages=shared,
                           payload=payload, n_payload_pages=len(snap_ids),
                           nbytes=nbytes)
        self.free_seq(seq_id)
        self.offloaded[seq_id] = rec
        return rec

    def restore(self, seq_id: int, tokens, *, reserve: int = 0,
                scatter: Optional[Callable] = None) -> Optional[RestoredSeq]:
        """Bring an offloaded sequence back on device.

        ``tokens`` must be the full context the checkpoint covers (prompt +
        generated-so-far) — it drives hashed-prefix re-sharing through the
        normal ``alloc_seq`` path, so prefix pages that survived on device
        are shared again instead of re-materialized.  The host snapshot is
        scattered into the freshly allocated private pages; logical pages
        ``[hit_pages, snap_start_page)`` — prefix pages whose donor evicted
        while this sequence was off-device — come back *empty* and the
        caller must recompute that token span (the engine re-prefills it).
        Returns None (checkpoint kept, no state change) when pages or rows
        are unavailable; the caller retries later.
        """
        rec = self.offloaded[seq_id]
        if len(tokens) != rec.length:
            raise ValueError(
                f"restore of seq {seq_id} got {len(tokens)} tokens but the "
                f"checkpoint covers {rec.length}")
        if not self.alloc_seq(seq_id, rec.length, tokens=list(tokens),
                              reserve=reserve):
            return None
        hit = self.prefix_hits.get(seq_id, 0)
        used = self.pages_needed(rec.length)
        start = max(hit, rec.shared_pages)
        restored = 0
        if start < used:
            dest = self.tables[seq_id][start:used]
            off = start - rec.shared_pages
            payload = jax.tree_util.tree_map(
                lambda a: a[:, off:off + len(dest)], rec.payload)
            scatter = (scatter if scatter is not None
                       else self._scatter_pages_local)
            scatter(list(dest), payload)
            restored = len(dest)
        del self.offloaded[seq_id]
        return RestoredSeq(hit_pages=hit, snap_start_page=rec.shared_pages,
                           length=rec.length, restored_pages=restored)

    def drop_offloaded(self, seq_id: int) -> Optional[OffloadedSeq]:
        """Discard a checkpoint without restoring (aborted while
        preempted)."""
        return self.offloaded.pop(seq_id, None)

    @property
    def offloaded_bytes(self) -> int:
        """Host bytes currently held by offloaded checkpoints."""
        return sum(rec.nbytes for rec in self.offloaded.values())

    # -------------------------------------------------------------- data path
    def _require_pools(self):
        if self.k_pages is None:
            raise RuntimeError(
                "PagedCache(alloc_pools=False) is bookkeeping-only: page "
                "payloads live in the engine's model cache tree, not here")

    def _ensure_writable(self, seq_id: int, start: int, end: int):
        """Copy-on-write: any page covering [start, end) that is shared
        (refcount > 1) is replaced by a private copy before writes land."""
        if end <= start:
            return
        table = self.tables[seq_id]
        dirty = False
        try:
            for li in range(start // self.page_size,
                            (end - 1) // self.page_size + 1):
                p = table[li]
                if self.refcount[p] > 1:
                    # engine flow shares only full, never-rewritten prefix
                    # pages, so COW is unreachable with alloc_pools=False
                    self._require_pools()
                    if not self.free_list:
                        raise RuntimeError(
                            "page pool exhausted during copy-on-write")
                    q = self.free_list.pop()
                    self.k_pages = self.k_pages.at[:, q].set(
                        self.k_pages[:, p])
                    self.v_pages = self.v_pages.at[:, q].set(
                        self.v_pages[:, p])
                    if self.k_scales is not None:
                        # scales travel with their pages: a COW'd payload
                        # dequantized against the donor's scales would be
                        # silently wrong after the follower rewrites either
                        self.k_scales = self.k_scales.at[:, q].set(
                            self.k_scales[:, p])
                        self.v_scales = self.v_scales.at[:, q].set(
                            self.v_scales[:, p])
                    self.refcount[p] -= 1
                    self.refcount[q] += 1
                    table[li] = q
                    dirty = True
        finally:
            # a partial COW (pool exhausted mid-loop) must still publish the
            # pages it did remap, or the device table would alias stale pages
            if dirty:
                self._sync_row(seq_id)

    @property
    def quantized(self) -> bool:
        return self.kv_quant is not None and self.kv_quant.quantized

    def _write_page_mode(self, seq_id: int, start: int,
                         k: jnp.ndarray, v: jnp.ndarray, layers):
        """Per-page-granularity write: each touched page is requantized over
        its whole valid extent with one (layer, page, head) scale — existing
        tokens are dequantized against the old scale, overlaid with the new
        span, and requantized (so appends into a partially-filled page keep
        one coherent scale; the extra rounding is the storage trade-off of
        per-page scales, DESIGN.md §12).  k, v: (len(layers), n, Hkv, D)."""
        ps = self.page_size
        n = k.shape[1]
        table = self.tables[seq_id]
        length = self.lengths[seq_id]
        lsel = jnp.asarray(layers, jnp.int32)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        for li in range(start // ps, (start + n - 1) // ps + 1):
            p = table[li]
            lo = li * ps
            valid = max(0, min(length, lo + ps) - lo)
            kf = KQ.dequantize(self.k_pages[lsel, p], self.k_scales[lsel, p])
            vf = KQ.dequantize(self.v_pages[lsel, p], self.v_scales[lsel, p])
            a, bnd = max(start, lo), min(start + n, lo + ps)
            kf = kf.at[:, a - lo:bnd - lo].set(k[:, a - start:bnd - start])
            vf = vf.at[:, a - lo:bnd - lo].set(v[:, a - start:bnd - start])
            # zero positions past the valid extent: stale payloads from a
            # recycled page must not inflate the page's amax
            mask = (jnp.arange(ps) < valid)[None, :, None, None]
            kq, ks = KQ.quantize(kf * mask, axes=(1, 3),
                                 scale_dtype=self.k_scales.dtype)
            vq, vs = KQ.quantize(vf * mask, axes=(1, 3),
                                 scale_dtype=self.v_scales.dtype)
            self.k_pages = self.k_pages.at[lsel, p].set(kq)
            self.v_pages = self.v_pages.at[lsel, p].set(vq)
            self.k_scales = self.k_scales.at[lsel, p].set(ks)
            self.v_scales = self.v_scales.at[lsel, p].set(vs)

    def write_tokens(self, seq_id: int, layer: int, start: int,
                     k: jnp.ndarray, v: jnp.ndarray):
        """k, v: (n, Hkv, D) written at logical positions [start, start+n).

        One batched scatter per (layer, call) — the seed's per-token
        ``.at[page, off].set()`` Python loop dispatched O(n) device ops.
        Shared pages are copy-on-write-resolved first.  Quantized caches
        scatter int8 payloads plus their scales (per-token granularity) or
        requantize the touched pages (per-page granularity).
        """
        self._require_pools()
        n = k.shape[0]
        self._ensure_writable(seq_id, start, start + n)
        if self.quantized and self.kv_quant.granularity == "page":
            self._write_page_mode(seq_id, start, k[None], v[None], [layer])
            return
        table = np.asarray(self.tables[seq_id], np.int32)
        pos = np.arange(start, start + n)
        pages = jnp.asarray(table[pos // self.page_size])
        offs = jnp.asarray(pos % self.page_size)
        if self.quantized:
            kq, ks = KQ.quantize(k, scale_dtype=self.k_scales.dtype)
            vq, vs = KQ.quantize(v, scale_dtype=self.v_scales.dtype)
            self.k_pages = self.k_pages.at[layer, pages, offs].set(kq)
            self.v_pages = self.v_pages.at[layer, pages, offs].set(vq)
            self.k_scales = self.k_scales.at[layer, pages, offs].set(ks)
            self.v_scales = self.v_scales.at[layer, pages, offs].set(vs)
        else:
            self.k_pages = self.k_pages.at[layer, pages, offs].set(
                k.astype(self.dtype))
            self.v_pages = self.v_pages.at[layer, pages, offs].set(
                v.astype(self.dtype))

    def write_prefill(self, seq_id: int, start: int,
                      k: jnp.ndarray, v: jnp.ndarray):
        """All-layer prefill write: k, v (n_layers, n, Hkv, D) at logical
        positions [start, start+n) — one scatter per pool for every layer."""
        self._require_pools()
        n = k.shape[1]
        self._ensure_writable(seq_id, start, start + n)
        if self.quantized and self.kv_quant.granularity == "page":
            self._write_page_mode(seq_id, start, k, v, range(self.n_layers))
            return
        table = np.asarray(self.tables[seq_id], np.int32)
        pos = np.arange(start, start + n)
        pages = jnp.asarray(table[pos // self.page_size])
        offs = jnp.asarray(pos % self.page_size)
        if self.quantized:
            kq, ks = KQ.quantize(k, scale_dtype=self.k_scales.dtype)
            vq, vs = KQ.quantize(v, scale_dtype=self.v_scales.dtype)
            self.k_pages = self.k_pages.at[:, pages, offs].set(kq)
            self.v_pages = self.v_pages.at[:, pages, offs].set(vq)
            self.k_scales = self.k_scales.at[:, pages, offs].set(ks)
            self.v_scales = self.v_scales.at[:, pages, offs].set(vs)
        else:
            self.k_pages = self.k_pages.at[:, pages, offs].set(
                k.astype(self.dtype))
            self.v_pages = self.v_pages.at[:, pages, offs].set(
                v.astype(self.dtype))

    def write_decode_token(self, seq_id: int, k: jnp.ndarray, v: jnp.ndarray):
        """Append one decode token's KV across every layer in one fused
        scatter.  k, v: (n_layers, Hkv, D); the token lands at position
        ``lengths[seq_id] - 1`` (call ``extend_seq`` first)."""
        self._require_pools()
        pos = self.lengths[seq_id] - 1
        if self.quantized and self.kv_quant.granularity == "page":
            self._write_page_mode(seq_id, pos, k[:, None], v[:, None],
                                  range(self.n_layers))
            return
        page = self.tables[seq_id][pos // self.page_size]
        off = pos % self.page_size
        if self.quantized:
            kq, ks = KQ.quantize(k, scale_dtype=self.k_scales.dtype)
            vq, vs = KQ.quantize(v, scale_dtype=self.v_scales.dtype)
            self.k_pages = self.k_pages.at[:, page, off].set(kq)
            self.v_pages = self.v_pages.at[:, page, off].set(vq)
            self.k_scales = self.k_scales.at[:, page, off].set(ks)
            self.v_scales = self.v_scales.at[:, page, off].set(vs)
        else:
            self.k_pages = self.k_pages.at[:, page, off].set(
                k.astype(self.dtype))
            self.v_pages = self.v_pages.at[:, page, off].set(
                v.astype(self.dtype))

    def gather_kv(self, seq_id: int, layer: int):
        """Returns (k, v): (len, Hkv, D) gathered via the block table —
        dequantized to ``compute_dtype`` when the pools store int8."""
        self._require_pools()
        table = jnp.asarray(self.tables[seq_id], jnp.int32)
        length = self.lengths[seq_id]
        k = self.k_pages[layer, table]
        v = self.v_pages[layer, table]
        if self.quantized:
            k = KQ.dequantize(k, self.k_scales[layer, table],
                              dtype=self.compute_dtype)
            v = KQ.dequantize(v, self.v_scales[layer, table],
                              dtype=self.compute_dtype)
        k = k.reshape(-1, self.kv_heads, self.head_dim)
        v = v.reshape(-1, self.kv_heads, self.head_dim)
        return k[:length], v[:length]

    # -------------------------------------------- speculative write rollback
    def spec_snapshot(self, seq_id: int) -> dict:
        """Checkpoint the state a k-token speculative write can disturb
        (data-path API, ``alloc_pools=True``): the payload bytes of the
        partially-filled tail page plus the current length/table extents.

        Per-token scales (and fp passthrough) don't strictly need the
        payload copy — positions past ``lengths`` are never read, so length
        rollback alone is lossless.  Per-*page* scales do: appending into a
        page requantizes the whole page against a new amax, so the retained
        prefix's bytes change.  ``truncate_seq(..., snapshot=...)``
        restores those bytes exactly, which is what makes the per-page
        requantize write path round-trip a rollback losslessly: re-writing
        the accepted tokens afterwards performs the identical
        dequant-overlay-requant computation a non-speculative append would
        have, byte for byte (tested)."""
        self._require_pools()
        length = self.lengths[seq_id]
        tail: Optional[dict] = None
        tail_page = None
        if length % self.page_size:
            tail_page = self.tables[seq_id][length // self.page_size]
            tail = self._gather_pages_local([tail_page])
        return {"length": length, "n_table": len(self.tables[seq_id]),
                "tail_page": tail_page, "tail": tail}

    def truncate_seq(self, seq_id: int, snapshot: dict) -> None:
        """Roll a speculative extension back to the snapshot: restore the
        tail page's payload bytes, free pages allocated past the snapshot's
        table extent, and reset ``lengths``.  The caller then re-appends
        the *accepted* tokens through the normal write path — under
        per-page scales that reproduces exactly the bytes of having only
        ever written them."""
        self._require_pools()
        if self.lengths[seq_id] < snapshot["length"]:
            raise ValueError(
                f"seq {seq_id} is shorter ({self.lengths[seq_id]}) than its "
                f"snapshot ({snapshot['length']}) — nothing to roll back")
        table = self.tables[seq_id]
        while len(table) > snapshot["n_table"]:
            p = table.pop()
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.free_list.append(p)
        if snapshot["tail"] is not None:
            # the tail page cannot have been COW-swapped meanwhile: spec
            # writes went through _ensure_writable, so it is private — but
            # its *identity* may differ from the snapshot's if a COW fired
            # during the speculative write; restore into the current page
            cur = table[snapshot["length"] // self.page_size]
            self._scatter_pages_local([cur], snapshot["tail"])
        self.lengths[seq_id] = snapshot["length"]
        self._sync_row(seq_id)
