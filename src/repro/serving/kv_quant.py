"""Quantized KV-cache subsystem (DESIGN.md §12).

At serving scale the KV cache, not the 4-bit weights, dominates memory:
QServe (W4A8KV4) and COMET (W4A4KV4) both show that quantizing it — with
dequantization fused into the attention kernel — multiplies effective cache
capacity, and therefore batch depth and throughput, at negligible accuracy
cost.  This module is the single source of that machinery:

* ``KVQuantConfig`` — what the cache stores: ``fp32``/``bf16`` passthrough
  or ``int8`` payloads with symmetric scales at ``token`` (one scale per
  written token per kv head) or ``page`` (one scale per physical page per
  kv head — the ``(P, Hkv)`` pool) granularity.
* ``quantize`` / ``dequantize`` — the symmetric round-to-nearest transform
  shared by every write/read fusion point (model cache tree, ``PagedCache``
  data path, kernel oracles).
* Byte accounting — ``page_bytes``/``slot_bytes``/``num_pages_for_budget``:
  with ``EngineConfig.page_pool_bytes`` the page pool is derived from a byte
  budget, so int8 KV roughly doubles (vs bf16) or quadruples (vs fp32) the
  pool — which the paged engine converts directly into deeper continuous
  batching.

Scale-pool layouts (parallel to the ``k_pages``/``v_pages`` payload pools,
one pool per K and V):

  token granularity : ``(..., P + 1, page_size, Hkv)``  — exact per write
  page granularity  : ``(..., P + 1, Hkv)``             — cheapest storage;
                      appends requantize the touched page (PagedCache data
                      path only — the engine's fused path is per-token)

Slot layout stores per-token scales as ``(B, max_len, Hkv)`` next to the
``(B, max_len, Hkv, D)`` int8 ``k``/``v``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

QMAX = 127.0                 # symmetric int8 range [-127, 127]
_SCALE_FLOOR = 1e-8          # an all-zero vector quantizes to zeros, not NaN

_KV_DTYPES = {
    "fp32": jnp.float32, "float32": jnp.float32,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
    "int8": jnp.int8,
}
_CANONICAL = {"float32": "fp32", "bfloat16": "bf16"}
_SCALE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}
GRANULARITIES = ("token", "page")


@dataclasses.dataclass(frozen=True)
class KVQuantConfig:
    """How the serving KV cache stores keys and values.

    ``dtype``: ``"fp32"``/``"bf16"`` are passthrough (no quantization —
    equivalent to setting the cache dtype); ``"int8"`` stores symmetric
    8-bit payloads plus a parallel scale pool.  ``granularity`` picks the
    scale resolution (``"token"`` or ``"page"``); ``scale_dtype`` the scale
    pool's storage dtype.
    """
    dtype: str = "int8"
    granularity: str = "token"
    scale_dtype: str = "float32"

    def __post_init__(self):
        if self.dtype not in _KV_DTYPES:
            raise ValueError(
                f"unknown KV-quant dtype {self.dtype!r}; expected one of "
                f"{sorted(set(_CANONICAL) | set(_CANONICAL.values()) | {'int8'})}")
        object.__setattr__(self, "dtype",
                           _CANONICAL.get(self.dtype, self.dtype))
        if self.granularity not in GRANULARITIES:
            raise ValueError(
                f"unknown KV-quant granularity {self.granularity!r}; "
                f"expected one of {GRANULARITIES}")
        if self.scale_dtype not in _SCALE_DTYPES:
            raise ValueError(
                f"KV-quant scale_dtype must be a float dtype "
                f"{sorted(_SCALE_DTYPES)}, got {self.scale_dtype!r}")

    @property
    def quantized(self) -> bool:
        return self.dtype == "int8"

    @property
    def jnp_dtype(self):
        """Payload storage dtype (int8 when quantized)."""
        return jnp.dtype(_KV_DTYPES[self.dtype])

    @property
    def scale_jnp_dtype(self):
        return jnp.dtype(_SCALE_DTYPES[self.scale_dtype])


# ------------------------------------------------------------- the transform
def quantize(x: jnp.ndarray, *, axes=(-1,), scale_dtype=jnp.float32):
    """Symmetric int8 quantization over ``axes``.

    Returns ``(q, scales)``: ``q`` is int8 with ``x``'s shape; ``scales`` has
    ``axes`` removed.  Per-token-per-head KV uses ``axes=(-1,)`` (reduce D);
    per-page uses ``axes=(position, D)``.  Scales are computed in fp32
    (``amax / 127``) then cast, so the round-trip error of one write is
    bounded by ``scale / 2``.
    """
    axes = tuple(a % x.ndim for a in axes)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, _SCALE_FLOOR) / QMAX
    q = jnp.clip(jnp.round(xf / scale), -QMAX, QMAX).astype(jnp.int8)
    scales = jnp.squeeze(scale, axis=axes).astype(scale_dtype)
    return q, scales


def dequantize(q: jnp.ndarray, scales: jnp.ndarray, *, dtype=jnp.float32):
    """Inverse of ``quantize``; granularity is inferred from the rank gap.

    ``q.ndim - scales.ndim == 1`` — per-token ``(..., Hkv)`` scales over
    ``(..., Hkv, D)`` payloads; ``== 2`` — per-page ``(..., Hkv)`` scales
    over ``(..., page_size, Hkv, D)`` payloads.
    """
    gap = q.ndim - scales.ndim
    if gap == 1:                       # token: broadcast over D
        s = scales[..., None]
    elif gap == 2:                     # page: broadcast over (position, D)
        s = scales[..., None, :, None]
    else:
        raise ValueError(
            f"scale rank {scales.ndim} does not match payload rank {q.ndim} "
            f"at token (gap 1) or page (gap 2) granularity")
    return (q.astype(jnp.float32) * s.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------- scale shapes
def paged_scale_shape(num_pages: int, page_size: int, kv_heads: int,
                      granularity: str) -> tuple[int, ...]:
    """Per-layer scale-pool shape parallel to a ``(num_pages + 1, page_size,
    Hkv, D)`` payload pool (null page included)."""
    if granularity == "token":
        return (num_pages + 1, page_size, kv_heads)
    if granularity == "page":
        return (num_pages + 1, kv_heads)
    raise ValueError(f"unknown granularity {granularity!r}")


# ------------------------------------------------------------ byte accounting
def default_num_pages(batch_slots: int, max_len: int, page_size: int) -> int:
    """The engine's capacity-equivalent page-pool default: the slot cache's
    worst-case token budget, shared across rows at page granularity."""
    return batch_slots * -(-max_len // page_size)
def _payload_itemsize(dtype, kv_quant: KVQuantConfig | None) -> int:
    if kv_quant is not None and kv_quant.quantized:
        return 1
    if kv_quant is not None:
        return kv_quant.jnp_dtype.itemsize
    return jnp.dtype(dtype).itemsize


def page_bytes(n_layers: int, kv_heads: int, head_dim: int, page_size: int, *,
               dtype=jnp.float32, kv_quant: KVQuantConfig | None = None) -> int:
    """Bytes of one *allocatable* page across all layers, K + V pools,
    scale pools included."""
    payload = (n_layers * 2 * page_size * kv_heads * head_dim
               * _payload_itemsize(dtype, kv_quant))
    if kv_quant is None or not kv_quant.quantized:
        return payload
    per_page = kv_heads if kv_quant.granularity == "page" \
        else page_size * kv_heads
    return payload + n_layers * 2 * per_page * kv_quant.scale_jnp_dtype.itemsize


def slot_bytes(n_layers: int, kv_heads: int, head_dim: int, batch_slots: int,
               max_len: int, *, dtype=jnp.float32,
               kv_quant: KVQuantConfig | None = None) -> int:
    """Bytes of the slot-layout cache (per-token scales when quantized)."""
    payload = (n_layers * 2 * batch_slots * max_len * kv_heads * head_dim
               * _payload_itemsize(dtype, kv_quant))
    if kv_quant is None or not kv_quant.quantized:
        return payload
    return payload + (n_layers * 2 * batch_slots * max_len * kv_heads
                      * kv_quant.scale_jnp_dtype.itemsize)


def num_pages_for_budget(budget_bytes: int, n_layers: int, kv_heads: int,
                         head_dim: int, page_size: int, *,
                         dtype=jnp.float32,
                         kv_quant: KVQuantConfig | None = None) -> int:
    """Allocatable pages a byte budget buys (the +1 null page is excluded —
    it exists in every configuration alike)."""
    per_page = page_bytes(n_layers, kv_heads, head_dim, page_size,
                          dtype=dtype, kv_quant=kv_quant)
    pages = int(budget_bytes) // per_page
    if pages <= 0:
        raise ValueError(
            f"page-pool byte budget {budget_bytes} buys zero pages "
            f"({per_page} bytes/page at page_size={page_size})")
    return pages
