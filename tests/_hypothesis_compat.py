"""``hypothesis`` import shim for the property tests.

Re-exports the real library when it is installed (``pip install -r
requirements-dev.txt``).  Otherwise provides a deterministic example-based
fallback so ``pytest`` still collects and runs the suite without the
dependency: each ``@given`` test executes the bound extremes first (all-min,
all-max) and then seeded random draws up to ``max_examples``.  Only the
subset of the API these tests use is implemented (``given``, ``settings``,
``strategies.integers``).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random

    class _IntStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def draw(self, rnd: random.Random) -> int:
            return rnd.randint(self.lo, self.hi)

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # read at call time from the outermost wrapper first, so
                # @settings works both above and below @given (hypothesis
                # documents the two orders as equivalent)
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 10))
                n = max(n, 1)
                rnd = random.Random(fn.__qualname__)    # per-test determinism
                examples = [tuple(s.lo for s in strats),
                            tuple(s.hi for s in strats)]
                examples += [tuple(s.draw(rnd) for s in strats)
                             for _ in range(max(n - 2, 0))]
                for ex in examples[:n]:
                    fn(*args, *ex, **kwargs)
            # hide the drawn params from pytest's fixture resolution (real
            # hypothesis does the same): signature must be () not (kw, n, ...)
            del wrapper.__wrapped__
            return wrapper
        return deco
