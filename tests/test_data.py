"""Data pipeline: determinism, host sharding, resume semantics, workload
stream statistics."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.data.pipeline import LMDataPipeline, sharegpt_stream


def test_deterministic_and_resumable():
    p1 = LMDataPipeline(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    p2 = LMDataPipeline(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    for s in (0, 5, 17):
        np.testing.assert_array_equal(p1.batch_at(s)["tokens"],
                                      p2.batch_at(s)["tokens"])
    # different steps differ
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])


def test_host_sharding_partitions_batch():
    full = LMDataPipeline(vocab_size=500, seq_len=8, global_batch=8, seed=1)
    h0 = LMDataPipeline(vocab_size=500, seq_len=8, global_batch=8, seed=1,
                        host_index=0, host_count=2)
    h1 = LMDataPipeline(vocab_size=500, seq_len=8, global_batch=8, seed=1,
                        host_index=1, host_count=2)
    assert h0.local_batch == h1.local_batch == 4
    b0, b1 = h0.batch_at(3)["tokens"], h1.batch_at(3)["tokens"]
    assert not np.array_equal(b0, b1)          # hosts draw distinct rows


def test_labels_are_shifted_tokens():
    p = LMDataPipeline(vocab_size=100, seq_len=12, global_batch=2, seed=0)
    b = p.batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 12)
    # next-token structure: labels[t] == tokens[t+1] comes from one stream
    # (verified by regenerating the underlying sequence)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


@given(st.integers(1, 50), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_sharegpt_stream_properties(n, seed):
    reqs = sharegpt_stream(n, vocab_size=1000, seed=seed, mean_prompt=8,
                           mean_output=4, max_prompt=32)
    assert len(reqs) == n
    arrivals = [r.arrival_s for r in reqs]
    assert arrivals == sorted(arrivals)
    for r in reqs:
        assert 1 <= r.prompt_len <= 32 and len(r.prompt) == r.prompt_len
        assert r.output_len >= 1
        assert all(0 <= t < 1000 for t in r.prompt)
