"""Sharding substrate tests: partition rules (divisibility sanitization,
quantized TP-only rule), multi-device jit equivalence, and the shard_map EP
MoE vs the einsum reference in a multi-device subprocess."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.sharding import partition as SP

ROOT = str(pathlib.Path(__file__).resolve().parents[1])
ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
# forward platform selection: without it a CPU container with libtpu baked in
# spends the whole subprocess timeout probing for TPU metadata
if "JAX_PLATFORMS" in os.environ:
    ENV["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]


def _run_sub(script: str) -> str:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=ENV, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_sanitize_spec():
    import repro.launch.mesh as M
    # single-device CPU mesh is enough to exercise the arithmetic
    mesh = M.make_mesh((1,), ("model",))
    spec = SP.sanitize_spec(P("model", None), (7, 4), mesh)
    assert spec == P("model", None)   # 7 % 1 == 0


SPEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.sharding import partition as SP

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("hymba_1p5b")       # vocab 32001: indivisible by 4
model = build_model(cfg)
params_abs = model.abstract_params()
sh = SP.param_shardings(params_abs, cfg, mesh)
flat = jax.tree_util.tree_leaves_with_path(sh, is_leaf=lambda s: hasattr(s, "spec"))
for path, s in flat:
    ps = "/".join(str(getattr(e, "key", getattr(e, "name", e))) for e in path)
    if "embedding" in ps:
        assert s.spec[0] is None, (ps, s.spec)   # 32001 not shardable by 4
print("SPEC_OK", len(flat))

# quantized weights: TP-only (no FSDP axis)
from repro.core.gptq import GPTQConfig
from repro.core.quantize_model import abstract_quantized_params
q_abs = abstract_quantized_params(params_abs, GPTQConfig(group_size=128))
qsh = SP.param_shardings(q_abs, cfg, mesh)
import jax.tree_util as tu
found = []
def chk(path, s):
    ps = "/".join(str(getattr(e, "key", getattr(e, "name", e))) for e in path)
    if ps.endswith("qweight"):
        assert "data" not in str(s.spec), (ps, s.spec)
        found.append(ps)
tu.tree_map_with_path(chk, qsh)
assert found
print("QSPEC_OK", len(found))
"""


@pytest.mark.slow
def test_partition_rules_multidevice():
    out = _run_sub(SPEC_SCRIPT)
    assert "SPEC_OK" in out and "QSPEC_OK" in out


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.launch.mesh import make_mesh
from repro.models import build_model, layers as L
from repro.models import ffn as F

mesh = make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(
    smoke_config("grok1_314b"), num_experts=8, num_experts_per_tok=2,
    capacity_factor=8.0)   # drop-free so beide paths agree exactly
rng = np.random.default_rng(0)
p = F.moe_init(jax.random.key(0), cfg, jnp.float32)
x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)).astype(np.float32))

y_ref, aux_ref = F.moe_apply(p, x, cfg=cfg)

L.set_moe_ep(mesh, "data", "model", ("data",))
cfg_ep = dataclasses.replace(cfg, moe_impl="ep")
with mesh:
    y_ep, aux_ep = jax.jit(
        lambda p, x: F.moe_apply_ep(p, x, cfg=cfg_ep),
        in_shardings=(NamedSharding(mesh, P()),
                      NamedSharding(mesh, P("data", None, None))),
        out_shardings=(NamedSharding(mesh, P("data", None, None)),
                       NamedSharding(mesh, P())))(p, x)
L.set_moe_ep(None, "", "", None)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=3e-3, atol=3e-3)
np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)
print("EP_OK")
"""


@pytest.mark.slow
def test_moe_ep_matches_einsum_multidevice():
    """shard_map expert-parallel MoE == einsum reference (8 fake devices)."""
    out = _run_sub(EP_SCRIPT)
    assert "EP_OK" in out


TRAIN_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.sharding import partition as SP
from repro.training import optimizer as O
from repro.training.train_loop import init_train_state, make_train_step

cfg = smoke_config("qwen3_4b")
model = build_model(cfg)
opt = O.OptimizerConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
state = init_train_state(model, opt, jax.random.key(0))
step = make_train_step(model, opt)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)}

_, m1 = jax.jit(step)(state, batch)        # single-device reference

mesh = make_mesh((2, 4), ("data", "model"))
psh = SP.param_shardings(state.params, cfg, mesh)
osh = SP.opt_state_shardings(state.opt_state, psh, mesh)
from repro.training.train_loop import TrainState
ssh = TrainState(params=psh, opt_state=osh, rng=SP.replicated(mesh))
bsh = SP.batch_specs(batch, cfg, mesh)
with mesh:
    _, m2 = jax.jit(step, in_shardings=(ssh, bsh))(state, batch)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
print("PARITY_OK", float(m1["loss"]), float(m2["loss"]))
"""


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = _run_sub(TRAIN_PARITY_SCRIPT)
    assert "PARITY_OK" in out
