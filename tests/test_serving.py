"""Serving substrate tests: engine end-to-end with continuous batching,
paged-cache bookkeeping, sampler properties, engine-vs-direct-decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedCache
from repro.serving.sampler import SamplingParams, sample


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_end_to_end(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=4, max_len=64, eos_id=-1)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(2, cfg.vocab_size, size=n).tolist(),
                       max_new_tokens=5) for n in (7, 13, 3, 9, 21, 4)]
    done = eng.run()
    assert sorted(f.rid for f in done) == sorted(rids)
    for f in done:
        assert len(f.output) == 5
        assert f.latency >= f.ttft >= 0.0
    assert eng.stats.tokens_generated > 0
    assert eng.slots.num_free == 4  # all slots released


def test_engine_matches_direct_decode(small_lm):
    """Engine output == hand-rolled greedy prefill+decode for one request."""
    cfg, model, params = small_lm
    rng = np.random.default_rng(1)
    prompt = rng.integers(2, cfg.vocab_size, size=9).tolist()
    eng = Engine(model, params, batch_slots=2, max_len=64, eos_id=-1)
    eng.submit(prompt, max_new_tokens=6)
    out_engine = eng.run()[0].output

    cache = model.init_cache(1, 64, dtype=jnp.float32)
    lens = jnp.zeros((1,), jnp.int32)
    logits, cache, lens = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache, lens)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(5):
        logits, cache, lens = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache, lens)
        toks.append(int(jnp.argmax(logits[0])))
    assert out_engine == toks


def test_engine_queue_exceeds_slots(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=2, max_len=32, eos_id=-1)
    rng = np.random.default_rng(2)
    n = 7
    for _ in range(n):
        eng.submit(rng.integers(2, cfg.vocab_size, size=5).tolist(),
                   max_new_tokens=3)
    done = eng.run()
    assert len(done) == n


def test_eos_stops_generation(small_lm):
    cfg, model, params = small_lm
    # find whichever token greedy decode produces first, use it as eos
    eng0 = Engine(model, params, batch_slots=1, max_len=32, eos_id=-1)
    eng0.submit([5, 6, 7], max_new_tokens=2)
    first = eng0.run()[0].output[0]
    eng = Engine(model, params, batch_slots=1, max_len=64, eos_id=first)
    eng.submit([5, 6, 7], max_new_tokens=50)
    done = eng.run()
    assert len(done[0].output) == 1   # stopped right at eos


def test_engine_fused_step_matches_unfused_reference(small_lm):
    """One decode step of the sync-free fused path produces exactly the tokens
    the legacy unfused path (model.decode_step + per-slot `sample`) would —
    for a live mix of greedy / temperature / top-k / top-p requests."""
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=4, max_len=64, eos_id=-1)
    rng = np.random.default_rng(3)
    sps = [SamplingParams(greedy=True),
           SamplingParams(temperature=0.8, top_k=5),
           SamplingParams(temperature=1.3, top_p=0.9)]
    for sp, plen in zip(sps, (5, 8, 11)):
        eng.submit(rng.integers(2, cfg.vocab_size, size=plen).tolist(),
                   max_new_tokens=4, sampling=sp)
    eng._admit([])        # reserve slots; prompts stream in as fused chunks
    eng.step()            # unbudgeted: one step lands all prompts + tok 0
    assert all(a.output and not a.pending_prefill
               for a in eng.sched.active.values())
    # deep-copy the snapshot: the engine donates its cache buffers into the
    # jitted step (on backends with donation), so the live tree is invalid
    # as a reference input after eng.step()
    cache0 = jax.tree_util.tree_map(jnp.copy, eng.slots.cache)
    lens0, rng0 = jnp.copy(eng.slots.seq_lens), eng.rng
    last = {s: a.output[-1] for s, a in eng.sched.active.items()}

    eng.step()

    # unfused reference against the pre-step snapshot, same per-slot keys
    bs = eng.slots.batch_slots
    tokens = np.zeros((bs, 1), np.int32)
    for s, tok in last.items():
        tokens[s, 0] = tok
    _, sub = jax.random.split(rng0)
    keys = jax.random.split(sub, bs)
    logits, _, _ = model.decode_step(params, jnp.asarray(tokens), cache0,
                                     lens0)
    for s, a in eng.sched.active.items():
        expect = int(sample(logits[s:s + 1], keys[s], a.req.sampling)[0])
        assert a.output[-1] == expect, (s, a.req.sampling)


def test_engine_decode_is_sync_free(small_lm, monkeypatch):
    """Each decode step makes exactly one device->host transfer (the sampled
    token vector) and never calls the legacy per-slot sampler."""
    import repro.serving.engine as engine_mod
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=2, max_len=32, eos_id=-1)
    rng = np.random.default_rng(4)
    for _ in range(2):
        eng.submit(rng.integers(2, cfg.vocab_size, size=5).tolist(),
                   max_new_tokens=4)
    eng._admit([])                        # prefill outside the decode loop

    transfers = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        transfers["n"] += 1
        return real_get(x)

    def no_legacy_sampler(*a, **k):
        raise AssertionError("legacy per-slot sampler ran in the decode loop")

    monkeypatch.setattr(engine_mod.jax, "device_get", counting_get)
    monkeypatch.setattr(engine_mod, "sample", no_legacy_sampler)
    steps = 3
    for _ in range(steps):
        eng.step()
    assert transfers["n"] == steps


def test_engine_mixed_sampling_end_to_end(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=3, max_len=64, eos_id=-1)
    rng = np.random.default_rng(5)
    rids = [
        eng.submit(rng.integers(2, cfg.vocab_size, size=6).tolist(),
                   max_new_tokens=5, sampling=sp)
        for sp in (SamplingParams(greedy=True),
                   SamplingParams(temperature=0.7, top_k=3),
                   SamplingParams(temperature=1.1, top_p=0.8))]
    done = eng.run()
    assert sorted(f.rid for f in done) == sorted(rids)
    for f in done:
        assert len(f.output) == 5
        assert all(0 <= t < cfg.vocab_size for t in f.output)


# ------------------------------------------------------------------ PagedCache
def test_paged_cache_alloc_free_cycle():
    pc = PagedCache(num_pages=16, page_size=4, n_layers=2, kv_heads=2, head_dim=8)
    assert pc.alloc_seq(0, 10)          # 3 pages
    assert pc.alloc_seq(1, 17)          # 5 pages
    assert pc.utilization == 8 / 16
    pc.free_seq(0)
    assert pc.utilization == 5 / 16
    assert pc.alloc_seq(2, 44)          # 11 pages available
    assert not pc.alloc_seq(3, 1)       # 0 left
    pc.free_seq(1); pc.free_seq(2)
    assert pc.utilization == 0.0


def test_paged_cache_prefix_sharing():
    pc = PagedCache(num_pages=8, page_size=4, n_layers=1, kv_heads=1, head_dim=4)
    assert pc.alloc_seq(0, 12)                       # 3 pages
    assert pc.alloc_seq(1, 12, share_from=0)         # shares all 3
    assert pc.utilization == 3 / 8                   # copy-free sharing
    pc.free_seq(0)
    assert pc.utilization == 3 / 8                   # still referenced by 1
    pc.free_seq(1)
    assert pc.utilization == 0.0


def test_paged_cache_write_gather_roundtrip():
    pc = PagedCache(num_pages=8, page_size=4, n_layers=1, kv_heads=2, head_dim=4,
                    dtype=jnp.float32)
    assert pc.alloc_seq(7, 10)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(10, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(10, 2, 4)), jnp.float32)
    pc.write_tokens(7, 0, 0, k, v)
    k2, v2 = pc.gather_kv(7, 0)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v), rtol=1e-6)


# -------------------------------------------------------------------- sampler
def test_sampler_greedy_and_topk():
    logits = jnp.asarray([[1.0, 3.0, 2.0, -1.0]])
    assert int(sample(logits, jax.random.key(0), SamplingParams(greedy=True))[0]) == 1
    # top_k=1 must equal greedy regardless of rng
    for seed in range(5):
        t = sample(logits, jax.random.key(seed), SamplingParams(top_k=1))
        assert int(t[0]) == 1


def test_sampler_top_p_restricts_support():
    logits = jnp.asarray([[10.0, 9.0, -10.0, -10.0]])
    seen = set()
    for seed in range(30):
        t = sample(logits, jax.random.key(seed), SamplingParams(top_p=0.95))
        seen.add(int(t[0]))
    assert seen <= {0, 1}
