"""Chunked paged-prefill kernel + prefill edge-case fixes (ISSUE 5).

Kernel-vs-ref parity for bf16/fp32 and both int8 scale granularities across
aligned and ragged ``write_lens``, kernel-on-hot-path dispatch (and the
gather oracle staying *off* it), greedy engine parity slot == paged ==
int8-paged on the prefix workload, the full-prefix-hit admission backoff,
null-page routing of overrun writes, the slot bucket-padding capacity fix,
``bucket_len`` edge cases, and the prefill peak-bytes memory model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels.paged_attention import paged_prefill
from repro.kernels.ref import flash_attention_ref, paged_prefill_ref
from repro.models import attention as A
from repro.models import build_model
from repro.perf import memory_model as MM
from repro.serving import kv_cache as KV
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine
from repro.serving.kv_quant import KVQuantConfig, quantize
from repro.serving.scheduler import bucket_len


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# -------------------------------------------------------------------- kernel
def _random_prefill(rng, b, s, h, hkv, d, pages, ps, maxp, starts, wlens):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pages, ps, hkv, d)), jnp.float32)
    bt = jnp.asarray((rng.permutation(pages - 1) + 1)[:b * maxp]
                     .reshape(b, maxp), jnp.int32)
    st = jnp.asarray(starts, jnp.int32)
    lens = st + jnp.asarray(wlens, jnp.int32)
    return q, kp, vp, bt, st, lens


@pytest.mark.parametrize("granularity", [None, "token", "page"])
@pytest.mark.parametrize("ragged", [False, True])
@pytest.mark.parametrize("h,hkv", [(8, 2), (4, 4)])
def test_paged_prefill_matches_ref(granularity, ragged, h, hkv):
    """Kernel vs gather oracle over (dtype-family) x (aligned, ragged
    write_lens) x GQA/MHA, including prefix-offset query positions."""
    rng = np.random.default_rng(0)
    b, s, d, pages, ps, maxp = 3, 8, 32, 40, 4, 7
    wlens = [5, 8, 3] if ragged else [s, s, s]
    q, kp, vp, bt, st, lens = _random_prefill(
        rng, b, s, h, hkv, d, pages, ps, maxp, [0, 4, 12], wlens)
    ks = vs = None
    if granularity is not None:
        axes = (-1,) if granularity == "token" else (1, 3)
        kp, ks = quantize(kp, axes=axes)
        vp, vs = quantize(vp, axes=axes)
    out = paged_prefill(q, kp, vp, bt, st, lens, k_scales=ks, v_scales=vs,
                        q_chunk=4)
    ref = paged_prefill_ref(q, kp, vp, bt, st, lens, k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_chunking_invariant():
    """Output is independent of the query chunking, including a chunk that
    does not divide S (internal padding path)."""
    rng = np.random.default_rng(1)
    q, kp, vp, bt, st, lens = _random_prefill(
        rng, 2, 8, 4, 2, 16, 24, 4, 5, [0, 4], [8, 6])
    outs = [paged_prefill(q, kp, vp, bt, st, lens, q_chunk=c)
            for c in (2, 3, 8, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)


def test_paged_prefill_matches_contiguous_flash_ref():
    """A cold full prefill through the block table agrees with plain causal
    attention over the same KV laid out contiguously."""
    rng = np.random.default_rng(2)
    b, s, h, hkv, d, ps, maxp = 2, 8, 4, 2, 16, 4, 2
    kc = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    kp = jnp.zeros((5, ps, hkv, d), jnp.float32)
    vp = jnp.zeros((5, ps, hkv, d), jnp.float32)
    for i in range(b):
        for lp in range(maxp):
            kp = kp.at[bt[i, lp]].set(kc[i, lp * ps:(lp + 1) * ps])
            vp = vp.at[bt[i, lp]].set(vc[i, lp * ps:(lp + 1) * ps])
    st = jnp.zeros((b,), jnp.int32)
    lens = jnp.full((b,), s, jnp.int32)
    out = paged_prefill(q, kp, vp, bt, st, lens, q_chunk=4)
    ref = flash_attention_ref(q, kc, vc, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_requires_both_scales():
    rng = np.random.default_rng(3)
    q, kp, vp, bt, st, lens = _random_prefill(
        rng, 1, 4, 2, 1, 8, 8, 4, 2, [0], [4])
    _, ks = quantize(kp)
    with pytest.raises(ValueError, match="both"):
        paged_prefill(q, kp, vp, bt, st, lens, k_scales=ks)


# ------------------------------------------------------------ write masking
def test_overrun_write_routes_to_null_page(small_lm):
    """A sequence running past its block table must not alias its write into
    the last table column's live page: the overflow position lands in the
    null page and every neighbor page is bit-identical afterwards."""
    cfg, model, params = small_lm
    p = A.gqa_init(jax.random.key(1), cfg)
    ps, maxp, pages = 4, 2, 5
    rng = np.random.default_rng(4)
    shape = (pages + 1, ps, cfg.num_kv_heads, cfg.head_dim)
    cache = {"k_pages": jnp.asarray(rng.normal(size=shape), jnp.float32),
             "v_pages": jnp.asarray(rng.normal(size=shape), jnp.float32)}
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    # row 0 sits exactly at capacity maxp*ps: its decode write has no cell
    seq_lens = jnp.asarray([maxp * ps, 1], jnp.int32)
    x = jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)), cfg.dtype)
    _, nc = A.gqa_apply(p, x, cfg=cfg, cache=cache, seq_lens=seq_lens,
                        block_tables=bt)
    for page in (1, 2, 4, 5):      # row 0's own pages + unowned neighbors
        np.testing.assert_array_equal(
            np.asarray(nc["k_pages"][page]), np.asarray(cache["k_pages"][page]),
            err_msg=f"page {page} corrupted by overrun write")
    # the overrun write went somewhere: the null page absorbed it
    assert not np.array_equal(np.asarray(nc["k_pages"][0]),
                              np.asarray(cache["k_pages"][0]))
    # row 1 (in range) still wrote normally: page 3, offset 1
    assert not np.array_equal(np.asarray(nc["k_pages"][3]),
                              np.asarray(cache["k_pages"][3]))


def test_slot_bucket_padding_never_writes_past_capacity(small_lm):
    """Regression (ISSUE 5): a prefill bucket overhanging the slot capacity
    used to clamp every padded position's write into cell cap-1.  Padded
    writes are dropped now — every cell past the true length stays
    bit-identical (zero), cap-1 included — and the last-real-token logits
    match an exact-length prefill."""
    cfg, model, params = small_lm
    cap, true_len, blen = 8, 5, 16          # bucket overhangs capacity
    toks = np.zeros((1, blen), np.int32)
    toks[0, :true_len] = [5, 6, 7, 8, 9]
    cache = model.init_cache(1, cap, dtype=jnp.float32)
    logits, cache2, _ = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, cache,
        jnp.zeros((1,), jnp.int32),
        true_lengths=jnp.asarray([true_len], jnp.int32))
    k = np.asarray(cache2["group0"]["attn"]["k"])
    assert np.all(k[:, :, true_len:] == 0.0), "padding leaked into the cache"
    assert np.any(k[:, :, :true_len] != 0.0)
    exact, _, _ = model.prefill(
        params, {"tokens": jnp.asarray(toks[:, :true_len])},
        model.init_cache(1, cap, dtype=jnp.float32),
        jnp.zeros((1,), jnp.int32),
        true_lengths=jnp.asarray([true_len], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(exact),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ scheduler
def test_bucket_len_edges():
    assert bucket_len(0) == 0               # was 32: a pure-padding prefill
    assert bucket_len(-3) == 0
    assert bucket_len(1) == 32
    assert bucket_len(32) == 32             # exact bucket
    assert bucket_len(33) == 64
    assert bucket_len(4096) == 4096
    assert bucket_len(4097) == 8192         # >4096 tail: 4096 multiples
    assert bucket_len(12289) == 16384


# --------------------------------------------------------------------- engine
def test_engine_paged_prefill_kernel_on_hot_path(small_lm, monkeypatch):
    """The paged prefill path must run the chunked Pallas kernel; the
    gather-materializing oracle must never be reachable from the engine."""
    cfg, model, params = small_lm
    calls = {"n": 0}
    real = A.PA.paged_prefill

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    def boom(*a, **k):
        raise AssertionError("gather oracle reached from the engine hot path")

    monkeypatch.setattr(A.PA, "paged_prefill", counting)
    monkeypatch.setattr(A.KR, "paged_prefill_ref", boom)
    eng = Engine(model, params, EngineConfig(batch_slots=2, max_len=32,
                                             eos_id=-1, cache="paged",
                                             page_size=4))
    eng.submit([5, 6, 7, 8, 9], max_new_tokens=2)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 2
    assert calls["n"] > 0                  # kernel traced on prefill


def test_engine_greedy_parity_slot_paged_int8(small_lm):
    """Greedy outputs are token-identical across slot, paged and int8-paged
    engines on the mixed-length prefix workload (suffix prefill included)."""
    cfg, model, params = small_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (7, 13, 3)]
    base = rng.integers(2, cfg.vocab_size, size=8).tolist()  # 2 full pages
    prompts.append(base + rng.integers(2, cfg.vocab_size, size=5).tolist())
    prompts.append(base + rng.integers(2, cfg.vocab_size, size=3).tolist())
    outs = {}
    for name, conf in (
            ("slot", EngineConfig(batch_slots=3, max_len=64, eos_id=-1)),
            ("paged", EngineConfig(batch_slots=3, max_len=64, eos_id=-1,
                                   cache="paged", page_size=4)),
            ("int8-paged", EngineConfig(batch_slots=3, max_len=64, eos_id=-1,
                                        cache="paged", page_size=4,
                                        kv_quant="int8"))):
        eng = Engine(model, params, conf)
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        outs[name] = {f.rid: f.output for f in eng.run()}
        if name != "slot":
            assert eng.stats.prefix_hit_pages > 0
    assert outs["slot"] == outs["paged"]
    assert outs["paged"] == outs["int8-paged"]


def test_engine_prefill_ref_impl_matches_kernel(small_lm):
    """The bench's gather-vs-kernel comparison is apples-to-apples: the
    ``paged_prefill_impl="ref"`` engine generates identical greedy tokens."""
    cfg, model, params = small_lm
    from repro.models import layers as L
    prompt = [5, 6, 7, 8, 9, 10, 11]
    outs = []
    for impl in ("kernel", "ref"):
        conf = EngineConfig(batch_slots=1, max_len=32, eos_id=-1,
                            cache="paged", page_size=4,
                            kernels=L.KernelConfig(paged_prefill_impl=impl))
        eng = Engine(model, params, conf)
        outs.append(eng.generate([prompt], max_new_tokens=4,
                                 ignore_eos=True)[0].output)
    assert outs[0] == outs[1]


def test_full_prefix_hit_recomputes_last_token(small_lm, monkeypatch):
    """Regression (ISSUE 5): a prefix hit covering the *whole* prompt used to
    prefill a zero-real-token bucket and sample the first token from padding
    logits.  With the admission backoff the last prompt page is recomputed:
    donor and follower are token-identical to a cold-cache run, and the
    donor's shared pages are swapped private before the rewrite."""
    cfg, model, params = small_lm
    rng = np.random.default_rng(5)
    prompt = rng.integers(2, cfg.vocab_size, size=8).tolist()  # 2 full pages

    def fresh():
        return Engine(model, params, EngineConfig(
            batch_slots=2, max_len=32, eos_id=-1, cache="paged", page_size=4))

    cold = fresh().generate([prompt], max_new_tokens=4,
                            ignore_eos=True)[0].output
    # simulate the historical uncapped prefix lookup (full-prompt coverage)
    monkeypatch.setattr(KV.PagedCache, "_max_shared_pages",
                        lambda self, n_tokens: n_tokens // self.page_size)
    eng = fresh()
    r0 = eng.submit(prompt, max_new_tokens=4, ignore_eos=True)
    r1 = eng.submit(prompt, max_new_tokens=4, ignore_eos=True)
    outs = {f.rid: f.output for f in eng.run()}
    assert outs[r0] == cold, "donor diverged from cold run"
    assert outs[r1] == cold, "full-prefix-hit follower diverged from cold run"
    # the hit was backed off to leave one recomputed page
    assert eng.stats.prefix_hit_pages == 1
    assert eng.pc.utilization == 0.0        # everything released cleanly


def test_release_prefix_swaps_only_shared_pages():
    pc = KV.PagedCache(num_pages=8, page_size=4, n_layers=1, kv_heads=1,
                       head_dim=4, alloc_pools=False)
    assert pc.alloc_seq(0, 8)
    assert pc.alloc_seq(1, 8, share_from=0)
    donor_table = list(pc.tables[0])
    assert pc.tables[1][:2] == donor_table[:2]
    assert pc.release_prefix(1, 1) == 1     # page 0 kept shared, page 1 swapped
    assert pc.tables[1][0] == donor_table[0]
    assert pc.tables[1][1] != donor_table[1]
    assert pc.tables[0] == donor_table      # donor untouched
    assert pc.refcount[donor_table[1]] == 1
    # device table follows the swap
    row = np.asarray(pc.block_tables[pc.row_of(1)])
    assert list(row[:2]) == pc.tables[1]
    assert pc.release_prefix(1, 0) == 1     # now swap the remaining shared one
    assert pc.tables[1][0] != donor_table[0]


# --------------------------------------------------------------- memory model
def test_paged_prefill_peak_bytes(small_lm):
    cfg, _, _ = small_lm
    kw = dict(batch=1, max_pages=8, page_size=16)
    gather = MM.paged_prefill_peak_bytes(cfg, dtype=jnp.float32,
                                         impl="gather", **kw)
    assert gather == 2 * 8 * 16 * cfg.num_kv_heads * cfg.head_dim * 4
    assert MM.paged_prefill_peak_bytes(cfg, impl="kernel", **kw) == 0
    int8 = MM.paged_prefill_peak_bytes(
        cfg, dtype=jnp.int8, kv_quant=KVQuantConfig(dtype="int8"),
        impl="gather", **kw)
    assert int8 > gather                    # gather + dense fp32 dequant copy
    with pytest.raises(ValueError, match="impl"):
        MM.paged_prefill_peak_bytes(cfg, impl="nope", **kw)
