"""Tensor-parallel serving tests (DESIGN.md §17): mesh builders, partition
specs, per-device page-pool accounting, device-labeled metrics, and — on a
CPU-simulated mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
— greedy-decode parity and host-bookkeeping equivalence between tp=1 and
tp>1 engines."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import get_strategy
from repro.core.quantize_model import quantize_params
from repro.launch import mesh as mesh_mod
from repro.models import build_model, layers as L
from repro.perf import memory_model as MM
from repro.serving import metrics as M
from repro.serving import parallel as PL
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices: XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8")
needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices: XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8")


@functools.lru_cache(maxsize=1)
def _qlm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    return cfg, model, qparams


@pytest.fixture(scope="module")
def qlm():
    return _qlm()


def _engine(model, qparams, tp, *, kv_quant=None, use_pallas=False):
    kern = L.KernelConfig(strategy=get_strategy("opt4gptq"),
                          use_pallas=use_pallas, block_sizes=(8, 64, 64))
    return Engine(model, qparams, EngineConfig(
        batch_slots=4, max_len=96, kernels=kern, eos_id=-1,
        cache="paged", page_size=16, kv_quant=kv_quant,
        mesh_shape=(tp,) if tp > 1 else None))


# a prompt set sharing a >= page_size token prefix so the prefix cache and
# COW paths are exercised, not just plain decode
PREFIX = list(range(1, 21))
PROMPTS = [PREFIX + [100 + i] for i in range(3)]


def _greedy(eng, prompts=PROMPTS, max_new=4):
    outs = eng.generate(prompts, max_new_tokens=max_new, ignore_eos=True)
    return [o.output for o in outs]


# -------------------------------------------------------------- mesh builders
def test_make_mesh_error_names_shape_and_devices():
    avail = len(jax.devices())
    shape = (avail + 1, 3)
    with pytest.raises(ValueError) as ei:
        mesh_mod.make_mesh(shape, ("data", "model"))
    msg = str(ei.value)
    assert str(shape) in msg
    assert str((avail + 1) * 3) in msg and str(avail) in msg
    assert "xla_force_host_platform_device_count" in msg


def test_make_host_mesh_subset_and_errors():
    mesh = mesh_mod.make_host_mesh(1)
    assert mesh.axis_names == ("model",) and mesh.devices.size == 1
    with pytest.raises(ValueError, match=">= 1"):
        mesh_mod.make_host_mesh(0)
    with pytest.raises(ValueError, match="1-D"):
        mesh_mod.make_host_mesh(1, axes=("data", "model"))
    n = len(jax.devices()) + 1
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        mesh_mod.make_host_mesh(n)


@needs2
def test_make_host_mesh_subset_of_devices():
    mesh = mesh_mod.make_host_mesh(2, axes=("tp",))
    assert mesh.devices.size == 2 and mesh.axis_names == ("tp",)


# ------------------------------------------------------------ per-device math
def test_paged_cache_device_bytes_halves_per_shard(qlm):
    cfg, _, _ = qlm
    full = MM.paged_cache_device_bytes(cfg, 8, 16)
    half = MM.paged_cache_device_bytes(cfg, 8, 16, tp=2)
    assert full == 2 * half
    i8 = MM.paged_cache_device_bytes(cfg, 8, 16, kv_quant="int8", tp=2)
    assert 0 < i8 < half
    with pytest.raises(ValueError, match="num_kv_heads"):
        MM.paged_cache_device_bytes(cfg, 8, 16, tp=3)


# ------------------------------------------------------------- config checks
def test_engine_config_mesh_validation():
    assert EngineConfig(cache="paged", mesh_shape=(2,)).mesh_shape == (2,)
    assert EngineConfig(cache="paged", mesh_shape=[2, 2]).mesh_shape == (2, 2)
    with pytest.raises(ValueError, match="mesh_shape"):
        EngineConfig(cache="paged", mesh_shape=())
    with pytest.raises(ValueError, match="mesh_shape"):
        EngineConfig(cache="paged", mesh_shape=(0,))
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(cache="slot", mesh_shape=(2,))
    with pytest.raises(ValueError, match="tp_axis"):
        EngineConfig(cache="paged", tp_axis="")


def test_build_tp_context_validation(qlm):
    _, model, qparams = qlm
    with pytest.raises(ValueError, match=">= 1"):
        PL.build_tp_context(model, qparams, 0)
    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        PL.build_tp_context(model, qparams, too_many)


# ---------------------------------------------------------------- spec rules
def test_param_specs_col_and_row_roles():
    tree = {"wq": {"w": np.zeros((8, 8))},
            "wo": {"w": np.zeros((8, 8))},
            "norm": {"scale": np.zeros((8,))}}
    specs = PL.param_specs(tree, "model", 2)
    from jax.sharding import PartitionSpec as P
    assert specs["wq"]["w"] == P(None, "model")     # col: N sharded
    assert specs["wo"]["w"] == P("model", None)     # row: K sharded
    assert specs["norm"]["scale"] == P()            # replicated


def test_param_specs_rejects_indivisible_and_row_bias():
    with pytest.raises(ValueError, match="does not divide"):
        PL.param_specs({"wq": {"w": np.zeros((8, 6))}}, "model", 4)
    with pytest.raises(ValueError, match="bias"):
        PL.param_specs({"wo": {"b": np.zeros((8,))}}, "model", 2)


def test_param_specs_rejects_act_order_row_parallel(qlm):
    _, _, qparams = qlm
    ql = qparams["group0"]["attn"]["wo"]["w"]
    perm = jnp.arange(ql.shape[0], dtype=jnp.int32)
    with pytest.raises(ValueError, match="act-order"):
        PL.param_specs({"wo": {"w": dataclasses.replace(ql, perm=perm)}},
                       "model", 2)
    # the same perm on a col-parallel projection is fine (K replicated)
    specs = PL.param_specs(
        {"wq": {"w": dataclasses.replace(ql, perm=perm)}}, "model", 2)
    assert specs is not None


def test_cache_specs_rejects_unknown_leaf():
    with pytest.raises(ValueError, match="unrecognized"):
        PL.cache_specs({"attn": {"weird": np.zeros((2, 2))}}, "model", 1)
    from jax.sharding import PartitionSpec as P
    specs = PL.cache_specs(
        {"attn": {"k_pages": np.zeros((4, 5, 16, 4, 8)),
                  "k_scales": np.zeros((4, 5, 16, 4))}}, "model", 2)
    assert specs["attn"]["k_pages"] == P(None, None, None, "model", None)
    assert specs["attn"]["k_scales"] == P(None, None, None, "model")


# ------------------------------------------------------ device-labeled gauges
def test_metrics_device_labels_parseable():
    m = M.make_engine_metrics("paged", "int8")
    m.configure_devices(2, 12345)

    class FakePC:
        def occupancy(self):
            return {"num_pages": 8, "free_pages": 5, "utilization": 0.375,
                    "offloaded_bytes": 1024.0}

    m.sync_pool(FakePC())
    parsed = M.parse_prometheus_text(m.registry.expose())
    for fam, want in (("engine_page_pool_device_free_pages", 5.0),
                      ("engine_page_pool_device_bytes", 12345.0),
                      ("engine_offloaded_bytes_device", 512.0)):
        samples = parsed[fam]["samples"]
        devs = {lbl["device"]: val for _, lbl, val in samples}
        assert devs == {"0": want, "1": want}, (fam, devs)


# --------------------------------------------------------------- mesh parity
@needs4
@pytest.mark.parametrize("kv_quant", [None, "bf16", "int8"])
def test_tp_greedy_parity_prefix_workload(qlm, kv_quant):
    """Greedy decode must be token-identical at tp=1 / tp=2 / tp=4 on the
    shared-prefix workload — the acceptance bar for the TP subsystem."""
    _, model, qparams = qlm
    outs = {tp: _greedy(_engine(model, qparams, tp, kv_quant=kv_quant))
            for tp in (1, 2, 4)}
    assert outs[1] == outs[2] == outs[4], outs


@needs2
def test_tp_greedy_parity_pallas_kernels(qlm):
    """Same bar through the Pallas GPTQ matmul/GEMV lanes (small blocks so
    the scale-block indexing actually tiles)."""
    _, model, qparams = qlm
    r1 = _greedy(_engine(model, qparams, 1, use_pallas=True))
    r2 = _greedy(_engine(model, qparams, 2, use_pallas=True))
    assert r1 == r2


@needs2
def test_tp_speculative_greedy_parity(qlm):
    """Speculation now runs under TP (ISSUE 10: the fused step is the one
    shard_map'd program, so the verify chunk needs no second wrapper):
    greedy spec output at tp=2 must match both its tp=1 twin and the
    non-speculative tp=2 engine."""
    from repro.serving.spec_decode import SpecConfig
    _, model, qparams = qlm

    def spec_engine(tp):
        return Engine(model, qparams, EngineConfig(
            batch_slots=4, max_len=96, eos_id=-1, cache="paged",
            page_size=16, speculation=SpecConfig(method="ngram", k=3),
            mesh_shape=(tp,) if tp > 1 else None))

    plain = _greedy(_engine(model, qparams, 2))
    s1, s2 = _greedy(spec_engine(1)), _greedy(spec_engine(2))
    assert s1 == s2 == plain


# the shim @given hides the test signature from pytest's fixture
# resolution, so the long-lived engine pair is a cached helper, not a fixture
@functools.lru_cache(maxsize=1)
def _tp_pair():
    _, model, qparams = _qlm()
    return (_engine(model, qparams, 1, kv_quant="int8"),
            _engine(model, qparams, 2, kv_quant="int8"))


def _pool_state(eng):
    pc = eng.pc
    return (sorted(pc.free_list), pc.refcount.tolist(),
            np.asarray(pc.block_tables).tolist(),
            eng.stats.prefix_hit_pages, eng.stats.prefix_hit_tokens)


@needs2
@given(st.integers(1, 3), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=4, deadline=None)
def test_tp_host_bookkeeping_matches_single_device(n, extra, seed):
    """Property: per-device pools keep the page *ids* global, so the host
    bookkeeping (free list, refcounts, block tables, prefix-cache hits) and
    the greedy outputs of a tp=2 engine must track a tp=1 engine exactly
    through identical workloads — both engines are long-lived, so state
    carries across examples on both sides identically."""
    e1, e2 = _tp_pair()
    rng = np.random.default_rng(seed)
    prompts = [PREFIX + rng.integers(1, 500, size=extra).tolist()
               for _ in range(n)]
    assert _greedy(e1, prompts, max_new=3) == _greedy(e2, prompts, max_new=3)
    assert _pool_state(e1) == _pool_state(e2)
