"""Fused-step execution tests (ISSUE 10 / DESIGN.md §18): token-budget
property parity against the unfused reference programs, per-bucket jit
recompile accounting, tolerance-aware greedy speculative acceptance, and the
``q_chunk`` KernelConfig / autotune plumbing."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import smoke_config
from repro.kernels import autotune
from repro.models import build_model
from repro.models.layers import KernelConfig
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine
from repro.serving.sampler import accept_speculative
from repro.serving.spec_decode import SpecConfig


@functools.lru_cache(maxsize=1)
def _lm():
    """Module-memoized smoke model — also used by the ``@given`` property
    tests (the hypothesis shim hides the wrapped signature from pytest, so
    those can't take fixtures)."""
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def small_lm():
    return _lm()


def _reference_greedy(model, params, prompt, max_new):
    """The unfused two-program path: whole-prompt ``prefill`` then 1-token
    ``decode_step`` calls — what the engine ran before the fused step."""
    cache = model.init_cache(1, 96, dtype=jnp.float32)
    lens = jnp.zeros((1,), jnp.int32)
    logits, cache, lens = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache, lens)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(max_new - 1):
        logits, cache, lens = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache, lens)
        toks.append(int(jnp.argmax(logits[0])))
    return toks


# --------------------------------------------------- budget-parity property
def _check_budget_parity(layout, seed, budget, stagger):
    """Property (ISSUE 10): for random prompt sets, random arrival
    interleavings, and random ``max_step_tokens`` budgets, greedy output is
    token-identical to the unfused prefill+decode reference — chunking a
    prompt across fused steps must not change a single token."""
    cfg, model, params = _lm()
    rng = np.random.default_rng(seed)
    max_new = 4
    prompts = [rng.integers(2, cfg.vocab_size, size=int(n)).tolist()
               for n in rng.integers(3, 24, size=3)]
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=96, eos_id=-1, cache=layout, page_size=4,
        max_step_tokens=budget))
    rids, fin = {}, []
    for i, p in enumerate(prompts):
        rids[eng.submit(p, max_new_tokens=max_new)] = i
        # interleave arrivals with engine progress per the stagger bits
        for _ in range((stagger >> (2 * i)) & 3):
            fin += eng.step()
    done = {f.rid: f.output for f in fin + eng.run()}
    assert done.keys() == rids.keys()
    for rid, i in rids.items():
        expect = _reference_greedy(model, params, prompts[i], max_new)
        assert done[rid] == expect, (layout, budget, i)


@given(st.integers(0, 2**31 - 1), st.integers(4, 40), st.integers(0, 255))
@settings(max_examples=4, deadline=None)
def test_budgeted_greedy_matches_unfused_slot(seed, budget, stagger):
    _check_budget_parity("slot", seed, budget, stagger)


@given(st.integers(0, 2**31 - 1), st.integers(4, 40), st.integers(0, 255))
@settings(max_examples=4, deadline=None)
def test_budgeted_greedy_matches_unfused_paged(seed, budget, stagger):
    _check_budget_parity("paged", seed, budget, stagger)


# ------------------------------------------------------- recompile accounting
def test_fused_program_compiles_once_per_bucket_mixed_traffic(small_lm,
                                                              monkeypatch):
    """Under mixed traffic — long chunked prefills landing alongside live
    decodes — the fused program traces once per step-width bucket, not per
    chunk length or batch composition."""
    cfg, model, params = small_lm
    traces = {"n": 0}
    orig = Engine._fused_step_impl

    def counting(*args, **kwargs):
        traces["n"] += 1                       # runs once per jit trace
        return orig(*args, **kwargs)

    monkeypatch.setattr(Engine, "_fused_step_impl", staticmethod(counting))
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=96, eos_id=-1, cache="paged", page_size=4,
        max_step_tokens=16))
    rng = np.random.default_rng(7)
    for n in (40, 10, 3):                      # chunks of 16/8/10/3 tokens
        eng.submit(rng.integers(2, cfg.vocab_size, size=n).tolist(),
                   max_new_tokens=4)
    done = eng.run()
    assert len(done) == 3 and all(len(f.output) == 4 for f in done)
    # every chunk width <= 16 shares the 32-wide bucket; decode-only steps
    # use the 1-wide bucket — exactly two traces for the whole run
    assert traces["n"] == 2, traces["n"]


# ------------------------------------------- tolerance-aware greedy acceptance
def test_greedy_tolerance_accepts_near_tied_argmax():
    """Regression for the documented ~1e-7 multi-token-vs-GEMV logit gap
    (ROADMAP §spec): the fused step scores drafts through the multi-token
    matmul lane while the drafts came from single-token GEMV decodes, whose
    different accumulation order can flip near-tied argmaxes.  Exact
    acceptance rejects such a draft; tolerance-aware acceptance keeps it."""
    v, k = 8, 2
    logits = np.full((1, k + 1, v), -5.0, np.float32)
    # position 0: draft token 3 sits 5e-8 below the argmax (token 4) — the
    # matmul-lane replay of a GEMV-lane argmax tie
    logits[0, 0, 4] = 0.0
    logits[0, 0, 3] = -5e-8
    logits[0, 1, 6] = 1.0            # position 1: draft 6 is the exact argmax
    logits[0, 2, 2] = 1.0            # bonus distribution argmax = 2
    drafts = jnp.asarray([[3, 6]], jnp.int32)
    lens = jnp.asarray([2], jnp.int32)

    n_exact, e_exact = accept_speculative(jnp.asarray(logits), drafts, lens,
                                          all_greedy=True)
    assert int(n_exact[0]) == 0              # 5e-8 flip kills the whole chain
    assert e_exact[0].tolist() == [4, 0, 0]

    n_tol, e_tol = accept_speculative(jnp.asarray(logits), drafts, lens,
                                      all_greedy=True, greedy_tol=1e-7)
    assert int(n_tol[0]) == 2                # both drafts survive the gap
    # the bonus token stays the exact argmax — tolerance never widens it
    assert e_tol[0].tolist() == [3, 6, 2]

    # a gap larger than the tolerance still rejects
    logits[0, 0, 3] = -1e-3
    n_far, _ = accept_speculative(jnp.asarray(logits), drafts, lens,
                                  all_greedy=True, greedy_tol=1e-7)
    assert int(n_far[0]) == 0


def test_greedy_tolerance_engine_knob(small_lm):
    """``SpecConfig.greedy_accept_tol`` threads end-to-end: with a tolerance
    far below the smoke model's logit gaps, speculative greedy output is
    identical to exact acceptance; the knob itself validates its domain."""
    cfg, model, params = small_lm
    rng = np.random.default_rng(11)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (12, 7)]

    def run(tol):
        eng = Engine(model, params, EngineConfig(
            batch_slots=2, max_len=96, eos_id=-1, cache="paged", page_size=4,
            speculation=SpecConfig(method="ngram", k=3,
                                   greedy_accept_tol=tol)))
        return [f.output for f in eng.generate(prompts, max_new_tokens=6,
                                               ignore_eos=True)]

    assert run(None) == run(1e-6)
    with pytest.raises(ValueError, match="greedy_accept_tol"):
        SpecConfig(greedy_accept_tol=-1e-7)


# --------------------------------------------------------- q_chunk validation
def test_kernel_config_q_chunk_validation():
    for ok in (None, "auto", 128, 256, 512):
        assert KernelConfig(q_chunk=ok).q_chunk == ok
    for bad in (0, -128, 64, 100, 129, True):
        with pytest.raises(ValueError, match="q_chunk"):
            KernelConfig(q_chunk=bad)


def test_autotune_q_chunk_cached_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "autotune.json"))
    autotune.clear_memory_cache()
    s, h, hkv, d, ps = 256, 4, 2, 16, 8
    qc = autotune.get_q_chunk(s, h, hkv, d, ps)
    assert qc in autotune.q_chunk_candidates(s)
    assert qc % 128 == 0
    timed = len(autotune.timed_keys)
    assert autotune.get_q_chunk(s, h, hkv, d, ps) == qc   # memory hit
    autotune.clear_memory_cache()
    assert autotune.get_q_chunk(s, h, hkv, d, ps) == qc   # file hit
    assert len(autotune.timed_keys) == timed              # no re-timing
    # candidates never exceed the suffix bucket: a 64-token suffix has only
    # the lane-minimum tile
    assert autotune.q_chunk_candidates(64) == [128]
