"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finiteness; decode-vs-full-forward
consistency for cache-bearing archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.models import build_model

# ~10 archs x 3 checks x several seconds each: slow tier (run via --runslow)
pytestmark = pytest.mark.slow

B, S = 2, 24


def _batch(cfg, rng=0):
    r = np.random.default_rng(rng)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "audio":
        batch["input_embeds"] = jnp.asarray(r.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["loss_mask"] = jnp.asarray(r.integers(0, 2, (B, S)), jnp.float32)
    elif cfg.frontend == "vision":
        batch["input_embeds"] = jnp.asarray(r.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["embed_mask"] = jnp.asarray(r.integers(0, 2, (B, S)), jnp.bool_)
    if cfg.mrope_sections:
        pos = np.broadcast_to(np.arange(S + cfg.meta_tokens), (3, B, S + cfg.meta_tokens))
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, cache, aux = model.apply(params, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grad(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, rng=2)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "hubert_xlarge"])
def test_decode_matches_full_forward(arch):
    """Prefill + incremental decode must reproduce the full-sequence logits."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    batch = _batch(cfg, rng=4)
    if cfg.frontend == "vision":  # decode path is text-only
        batch.pop("input_embeds"); batch.pop("embed_mask")
    if cfg.mrope_sections:
        batch.pop("positions")  # text-only: default positions == M-RoPE on text
    tokens = batch["tokens"]

    full_logits, _, _ = model.apply(params, {k: v for k, v in batch.items()
                                             if k != "labels"}, mode="train")

    max_len = S + 8
    cache = model.init_cache(B, max_len, dtype=jnp.float32)
    seq_lens = jnp.zeros((B,), jnp.int32)
    split = S - 4
    logits_p, cache, seq_lens = model.prefill(
        params, {"tokens": tokens[:, :split]}, cache, seq_lens)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, split - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(split, S):
        logits_d, cache, seq_lens = model.decode_step(
            params, tokens[:, t:t + 1], cache, seq_lens)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} decode step {t} diverged from full forward")


def test_param_count_sane():
    from repro.configs import get_config
    # analytic counts should land near the published sizes
    for arch, lo, hi in [("qwen1p5_110b", 95e9, 120e9),
                         ("grok1_314b", 290e9, 330e9),
                         ("falcon_mamba_7b", 6e9, 8.5e9),
                         ("hymba_1p5b", 1.0e9, 2.1e9),
                         ("deepseek_v2_lite_16b", 13e9, 18e9)]:
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
