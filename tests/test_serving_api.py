"""Request-lifecycle API tests (ISSUE 3): EngineConfig construction + the
deprecated-kwarg shim, stream-vs-run token parity on both cache layouts,
abort resource release (slots, paged free list / refcounts / prefix cache),
per-request stop criteria + finish_reason, submit-time validation, and an
HTTP round-trip against the /v1/completions front-end."""
import json
import threading
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving.api import (EngineConfig, FinishReason, RequestState,
                               StreamEvent)
from repro.serving.engine import Engine
from repro.serving.sampler import SamplingParams


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, size=n).tolist() for n in sizes]


# ------------------------------------------------------------- EngineConfig
def test_engine_config_construction_and_shim(small_lm):
    cfg, model, params = small_lm
    econf = EngineConfig(batch_slots=2, max_len=32, eos_id=-1)
    eng = Engine(model, params, econf)
    assert eng.config is econf and eng.max_len == 32

    # the deprecated kwarg shim still works, and warns
    with pytest.warns(DeprecationWarning):
        eng2 = Engine(model, params, batch_slots=2, max_len=32, eos_id=-1)
    assert eng2.config == econf

    # but mixing both spellings is an error
    with pytest.raises(TypeError, match="not both"):
        Engine(model, params, econf, batch_slots=2)


def test_engine_config_validates():
    with pytest.raises(ValueError, match="batch_slots"):
        EngineConfig(batch_slots=0)
    with pytest.raises(ValueError, match="max_len"):
        EngineConfig(max_len=-1)
    with pytest.raises(ValueError, match="num_pages"):
        EngineConfig(cache="paged", num_pages=0)
    with pytest.raises(ValueError, match="cache layout"):
        EngineConfig(cache="ring")


# ------------------------------------------------------- stream/run parity
@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_stream_matches_run_token_parity(small_lm, layout):
    """Greedy stream() output is token-identical to run() on both layouts,
    and per-token StreamEvents carry correct indices/terminal outputs."""
    cfg, model, params = small_lm
    econf = EngineConfig(batch_slots=3, max_len=64, eos_id=-1,
                         cache=layout, page_size=4)
    prompts = _prompts(cfg, (7, 13, 3, 9), seed=1)

    eng_run = Engine(model, params, econf)
    for p in prompts:
        eng_run.submit(p, max_new_tokens=5)
    ref = {f.rid: f.output for f in eng_run.run()}

    eng_str = Engine(model, params, econf)
    rids = [eng_str.submit(p, max_new_tokens=5) for p in prompts]
    got = {r: [] for r in rids}
    terminal = {}
    for ev in eng_str.stream():
        assert isinstance(ev, StreamEvent)
        assert ev.index == len(got[ev.rid])
        got[ev.rid].append(ev.token)
        if ev.finish_reason is not None:
            terminal[ev.rid] = ev
    assert got == ref
    for rid, ev in terminal.items():
        assert ev.output.output == ref[rid]
        assert ev.finish_reason == FinishReason.LENGTH
        assert eng_str.state_of(rid) == RequestState.FINISHED


def test_generate_blocking_convenience(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=2, max_len=64,
                                             eos_id=-1))
    prompts = _prompts(cfg, (5, 9, 3), seed=2)
    outs = eng.generate(prompts, max_new_tokens=4)
    assert [o.rid for o in outs] == sorted(o.rid for o in outs)  # order kept
    for o, p in zip(outs, prompts):
        assert o.prompt_len == len(p)
        assert len(o.output) == 4
        assert o.finish_reason == FinishReason.LENGTH
        assert o.latency >= o.ttft > 0.0
        assert o.tpot > 0.0


# ------------------------------------------------------------------ abort
def test_abort_queued_request(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=1, max_len=32,
                                             eos_id=-1))
    p1, p2 = _prompts(cfg, (5, 5), seed=3)
    r1 = eng.submit(p1, max_new_tokens=3)
    r2 = eng.submit(p2, max_new_tokens=3)
    assert eng.state_of(r2) == RequestState.QUEUED
    out = eng.abort(r2)
    assert out.finish_reason == FinishReason.ABORT and out.output == []
    assert eng.state_of(r2) == RequestState.ABORTED
    assert out.ttft == 0.0 and out.tpot == 0.0    # no-first-token sentinel
    done = eng.run()
    assert [f.rid for f in done] == [r1]
    assert eng.abort(r1) is None            # already finished -> no-op


def test_abort_mid_decode_frees_slot(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=2, max_len=64,
                                             eos_id=-1))
    p1, p2 = _prompts(cfg, (6, 8), seed=4)
    r1 = eng.submit(p1, max_new_tokens=20)
    eng.submit(p2, max_new_tokens=6)
    eng.step(); eng.step()
    out = eng.abort(r1)
    assert out.finish_reason == FinishReason.ABORT
    assert 0 < len(out.output) < 20         # partial output preserved
    done = eng.run()
    assert [f.rid for f in done] != [r1]
    assert eng.slots.num_free == 2          # aborted slot released
    assert eng.sched.idle


def test_abort_mid_decode_restores_paged_baseline(small_lm):
    """Aborting mid-flight returns the paged free list, refcounts and
    block-table rows to their pre-request values — including pages shared
    through the prefix cache."""
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=3, max_len=64,
                                             eos_id=-1, cache="paged",
                                             page_size=4))
    rng = np.random.default_rng(5)
    base = rng.integers(2, cfg.vocab_size, size=8).tolist()   # 2 full pages
    p1 = base + rng.integers(2, cfg.vocab_size, size=5).tolist()
    p2 = base + rng.integers(2, cfg.vocab_size, size=3).tolist()

    free0 = sorted(eng.pc.free_list)
    rc0 = eng.pc.refcount.copy()
    r1 = eng.submit(p1, max_new_tokens=16)
    r2 = eng.submit(p2, max_new_tokens=16)
    eng.step(); eng.step()                  # both admitted (prefix shared)
    assert eng.stats.prefix_hit_pages > 0
    out = eng.abort(r1)                     # donor of the shared prefix
    assert out.finish_reason == FinishReason.ABORT
    done = eng.run()                        # drain the survivor
    assert [f.rid for f in done] == [r2]
    assert sorted(eng.pc.free_list) == free0
    np.testing.assert_array_equal(eng.pc.refcount, rc0)
    assert eng.pc.utilization == 0.0
    assert not eng.pc.rows and not eng.pc.tables


def test_abort_surfaces_in_stream(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=2, max_len=64,
                                             eos_id=-1))
    (p1,) = _prompts(cfg, (5,), seed=6)
    r1 = eng.submit(p1, max_new_tokens=30)
    events = []
    for ev in eng.stream():
        events.append(ev)
        if len(events) == 2:
            eng.abort(r1)
    terminal = events[-1]
    assert terminal.rid == r1 and terminal.token is None
    assert terminal.finish_reason == FinishReason.ABORT
    assert len(terminal.output.output) >= 2


# ------------------------------------------------------------ stop criteria
@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_stop_token_truncation_and_finish_reason(small_lm, layout):
    cfg, model, params = small_lm
    econf = EngineConfig(batch_slots=1, max_len=64, eos_id=-1,
                         cache=layout, page_size=4)
    (p,) = _prompts(cfg, (5,), seed=7)
    full = Engine(model, params, econf).generate(
        [p], max_new_tokens=6)[0].output
    assert len(full) == 6

    # stop on the 3rd greedy token: output truncates right after it
    out = Engine(model, params, econf).generate(
        [p], max_new_tokens=6, stop_token_ids=(full[2],))[0]
    assert out.output == full[:3]
    assert out.finish_reason == FinishReason.STOP


def test_eos_vs_ignore_eos_finish_reason(small_lm):
    cfg, model, params = small_lm
    (p,) = _prompts(cfg, (5,), seed=8)
    probe = EngineConfig(batch_slots=1, max_len=64, eos_id=-1)
    full = Engine(model, params, probe).generate(
        [p], max_new_tokens=6)[0].output

    econf = EngineConfig(batch_slots=1, max_len=64, eos_id=full[1])
    out = Engine(model, params, econf).generate([p], max_new_tokens=6)[0]
    assert out.output == full[:2]
    assert out.finish_reason == FinishReason.STOP

    out2 = Engine(model, params, econf).generate(
        [p], max_new_tokens=6, ignore_eos=True)[0]
    assert out2.output == full
    assert out2.finish_reason == FinishReason.LENGTH


# -------------------------------------------------------------- validation
def test_submit_rejects_over_capacity_both_layouts(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=1, max_len=32,
                                             eos_id=-1))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(list(range(2, 30)), max_new_tokens=8)
    engp = Engine(model, params, EngineConfig(batch_slots=1, max_len=32,
                                              eos_id=-1, cache="paged",
                                              page_size=4))
    with pytest.raises(ValueError, match="pages"):
        engp.submit(list(range(2, 30)), max_new_tokens=8)


def test_submit_validates_sampling_params(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=1, max_len=32,
                                             eos_id=-1))
    ok = [5, 6, 7]
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(ok, sampling=SamplingParams(temperature=-0.5))
    with pytest.raises(ValueError, match="top_p"):
        eng.submit(ok, sampling=SamplingParams(top_p=0.0))
    with pytest.raises(ValueError, match="top_p"):
        eng.submit(ok, sampling=SamplingParams(top_p=1.5))
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(ok, sampling=SamplingParams(top_k=-1))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(ok, sampling=SamplingParams(top_k=cfg.vocab_size))
    with pytest.raises(ValueError, match="empty"):
        eng.submit([])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(ok, max_new_tokens=0)
    assert not eng._requests                 # nothing was queued


# ------------------------------------------------------------- HTTP server
@pytest.fixture()
def http_server(small_lm):
    from repro.serving.http_api import make_server
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(batch_slots=2, max_len=64,
                                             eos_id=-1))
    server = make_server(eng, port=0, model_name=cfg.name)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    yield cfg, server
    server.shutdown()


def _post(port, body, timeout=120):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/completions",
        data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    return urllib.request.urlopen(req, timeout=timeout)


def test_http_completions_roundtrip(small_lm, http_server):
    """Blocking and SSE-streamed completions over real HTTP agree token-for-
    token, carry OpenAI-style fields, and bad requests get a 400."""
    cfg, server = http_server
    port = server.port
    prompt = _prompts(cfg, (6,), seed=9)[0]

    resp = json.load(_post(port, {"prompt": prompt, "max_tokens": 4,
                                  "temperature": 0}))
    assert resp["object"] == "text_completion"
    choice = resp["choices"][0]
    assert len(choice["token_ids"]) == 4
    assert choice["finish_reason"] == "length"
    assert resp["usage"] == {"prompt_tokens": 6, "completion_tokens": 4,
                             "total_tokens": 10}
    assert resp["metrics"]["ttft_s"] > 0.0

    # SSE stream: one data: chunk per token, then [DONE]
    streamed, done = [], False
    with _post(port, {"prompt": prompt, "max_tokens": 4, "temperature": 0,
                      "stream": True}) as r:
        assert r.headers["Content-Type"].startswith("text/event-stream")
        for line in r:
            line = line.decode().strip()
            if not line.startswith("data: "):
                continue
            if line[6:] == "[DONE]":
                done = True
                break
            streamed += json.loads(line[6:])["choices"][0]["token_ids"]
    assert done
    assert streamed == choice["token_ids"]   # greedy parity with blocking

    with pytest.raises(urllib.error.HTTPError) as e:
        _post(port, {"prompt": "not token ids"})
    assert e.value.code == 400

    models = json.load(urllib.request.urlopen(
        f"http://127.0.0.1:{port}/v1/models", timeout=30))
    assert models["data"][0]["id"] == cfg.name


def test_http_stop_tokens(small_lm, http_server):
    cfg, server = http_server
    port = server.port
    prompt = _prompts(cfg, (6,), seed=10)[0]
    full = json.load(_post(port, {"prompt": prompt, "max_tokens": 5,
                                  "temperature": 0}))["choices"][0]
    stop_tok = full["token_ids"][1]
    resp = json.load(_post(port, {"prompt": prompt, "max_tokens": 5,
                                  "temperature": 0, "stop": stop_tok}))
    assert resp["choices"][0]["token_ids"] == full["token_ids"][:2]
    assert resp["choices"][0]["finish_reason"] == "stop"
