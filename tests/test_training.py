"""Training substrate: optimizer math, loss decrease, grad accumulation
equivalence, checkpoint/restart (incl. kill-and-resume and torn-write
rejection), elastic re-shard in a multi-device subprocess."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import smoke_config
from repro.data.pipeline import LMDataPipeline
from repro.models import build_model
from repro.runtime.fault_tolerance import (InjectedFailure,
                                           resilient_train_loop)
from repro.training import optimizer as O
from repro.training.train_loop import init_train_state, make_train_step


def _setup(arch="qwen3_4b", lr=3e-3):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    opt_cfg = O.OptimizerConfig(learning_rate=lr, warmup_steps=2,
                                total_steps=100)
    state = init_train_state(model, opt_cfg, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt_cfg))
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=7)
    return cfg, model, opt_cfg, state, step, pipe


def _to_batch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases():
    cfg, model, opt_cfg, state, step, pipe = _setup()
    losses = []
    batch = _to_batch(pipe.batch_at(0))  # overfit one batch
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_lr_schedule_shape():
    cfg = O.OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(O.lr_schedule(cfg, jnp.asarray(float(s)))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5 * lrs[2] / 1.0) < 0.3    # mid-warmup
    assert lrs[2] == pytest.approx(1.0, rel=0.05)
    assert lrs[4] == pytest.approx(0.1, rel=0.05)    # floor


def test_grad_accum_matches_full_batch():
    cfg, model, opt_cfg, state, _, pipe = _setup()
    batch = _to_batch(pipe.batch_at(3))
    s1 = jax.jit(make_train_step(model, opt_cfg, accum_steps=1))
    s2 = jax.jit(make_train_step(model, opt_cfg, accum_steps=2))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    # same data -> same mean loss and near-identical params after one update
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    l1 = jax.tree_util.tree_leaves(st1.params)
    l2 = jax.tree_util.tree_leaves(st2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, opt_cfg, state, step, pipe = _setup()
    ck = Checkpointer(tmp_path, keep=2)
    state, _ = step(state, _to_batch(pipe.batch_at(0)))
    ck.save(0, state, extra={"next_step": 1})
    restored, extra = ck.restore(state)
    assert extra["next_step"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    cfg, model, opt_cfg, state, step, pipe = _setup()
    ck = Checkpointer(tmp_path, keep=2)
    for s in range(5):
        ck.save(s, {"x": jnp.asarray([s])})
    assert ck.all_steps() == [3, 4]


def test_torn_checkpoint_rejected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(3, {"x": jnp.arange(4)})
    # simulate a crash mid-write of step 7: directory exists, no COMMIT marker
    torn = pathlib.Path(tmp_path) / "step_7"
    torn.mkdir()
    (torn / "arr_0.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 3
    restored, _ = ck.restore({"x": jnp.zeros(4, jnp.int32)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(4))


def test_kill_and_resume_training(tmp_path):
    """Crash after step 12 (post-update, pre-commit) -> resume from step 9's
    checkpoint -> final state must equal an uninterrupted run (deterministic
    data replay makes this exact)."""
    cfg, model, opt_cfg, state0, step, pipe = _setup()
    total = 17

    # uninterrupted reference
    ref = state0
    for s in range(total):
        ref, _ = step(ref, _to_batch(pipe.batch_at(s)))

    ck = Checkpointer(tmp_path / "ft", keep=3)
    with pytest.raises(InjectedFailure):
        resilient_train_loop(step, state0, pipe, steps=total, ckpt=ck,
                             ckpt_every=5, async_ckpt=False, fail_at_step=12,
                             to_batch=_to_batch)
    assert ck.latest_step() == 9      # steps 0-9 committed at (step+1)%5==0
    state, log, start = resilient_train_loop(
        step, state0, pipe, steps=total, ckpt=ck, ckpt_every=5,
        async_ckpt=False, to_batch=_to_batch)
    assert start == 10                # resumed, not restarted
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_async_checkpoint_equivalent(tmp_path):
    cfg, model, opt_cfg, state, step, pipe = _setup()
    ck_sync = Checkpointer(tmp_path / "s")
    ck_async = Checkpointer(tmp_path / "a")
    state, _ = step(state, _to_batch(pipe.batch_at(0)))
    ck_sync.save(0, state)
    ck_async.save(0, state, blocking=False)
    ck_async.wait()
    r1, _ = ck_sync.restore(state)
    r2, _ = ck_async.restore(state)
    for a, b in zip(jax.tree_util.tree_leaves(r1), jax.tree_util.tree_leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_mesh

ckdir = sys.argv[1]
# save on a (4, 2) mesh
mesh_a = make_mesh((4, 2), ("data", "model"))
w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
ck = Checkpointer(ckdir)
ck.save(0, {"w": w_a})
# restore on a (2, 4) mesh — elastic re-shard
mesh_b = make_mesh((2, 4), ("data", "model"))
sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
restored, _ = ck.restore({"w": w}, shardings=sh)
assert restored["w"].sharding.num_devices == 8
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("ELASTIC_OK")
"""


def test_elastic_reshard_multidevice(tmp_path):
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # forward platform selection: without it a CPU container with libtpu
    # baked in spends the whole subprocess timeout probing for TPU metadata
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT, str(tmp_path / "ck")],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
