"""Prefix-cache persistence tests (ISSUE 8 satellite / DESIGN.md §16):
serialize the hashed prefix index + page payloads to a directory and warm-
start a fresh engine from it — deterministic sha256-seeded hash chain,
warm-restart hit rate, greedy output identity, and quant-mode safety."""
import hashlib
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedCache, prefix_hash_seed
from repro.serving.sampler import SamplingParams

GREEDY = SamplingParams(greedy=True)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, seed=0):
    """Two prompts sharing a 16-token (= 2 page_size=8 pages) prefix."""
    rng = np.random.default_rng(seed)
    base = rng.integers(2, cfg.vocab_size, size=16).tolist()
    return [base + rng.integers(2, cfg.vocab_size, size=5).tolist(),
            base + rng.integers(2, cfg.vocab_size, size=3).tolist()]


def _conf(**kw):
    return EngineConfig(batch_slots=3, max_len=64, eos_id=-1, cache="paged",
                        page_size=8, **kw)


# --------------------------------------------------------------- hash chain
def test_prefix_hash_seed_is_sha256_derived():
    tag = ("fp", "float32")
    want = int.from_bytes(
        hashlib.sha256(repr(("kv_prefix_seed_v1", 8) + tag).encode())
        .digest()[:8], "big", signed=True)
    assert prefix_hash_seed(tag, 8) == want


def test_hash_chain_deterministic_across_instances():
    """Two caches with the same config hash identical prefixes to identical
    keys — the property persistence depends on (Python's string hash is
    process-seeded; ints/tuples are not)."""
    mk = lambda: PagedCache(num_pages=8, page_size=4, n_layers=1,
                            kv_heads=1, head_dim=4)
    a, b = mk(), mk()
    toks = list(range(2, 14))
    assert a._hash_seed == b._hash_seed
    assert a._prefix_keys(toks) == b._prefix_keys(toks)
    # quant modes and page sizes key disjoint chains
    c = PagedCache(num_pages=8, page_size=8, n_layers=1, kv_heads=1,
                   head_dim=4)
    assert c._hash_seed != a._hash_seed


# ------------------------------------------------------------ save / restore
def test_warm_restart_hits_and_greedy_identity(small_lm, tmp_path):
    """Engine A publishes shared-prefix pages, saves them mid-run; engine B
    restarts from the directory and serves the same prompts with prefix
    hits from step one and token-identical greedy output."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, seed=1)
    path = str(tmp_path / "warm")

    a = Engine(model, params, _conf())
    for p in prompts:
        a.submit(p, max_new_tokens=6, sampling=GREEDY, ignore_eos=True)
    for _ in range(3):          # both admitted: prefix pages live+published
        a.step()
    saved = a.save_prefix_cache(path)
    assert saved >= 2           # the two shared full pages (at least)
    ref = {o.rid: o.output for o in a.run()}
    assert os.path.exists(os.path.join(path, "index.json"))
    assert os.path.exists(os.path.join(path, "pages.npz"))

    b = Engine(model, params, _conf(prefix_cache_path=path))
    outs = b.generate(prompts, max_new_tokens=6, sampling=GREEDY,
                      ignore_eos=True)
    assert b.stats.prefix_hit_pages > 0, "warm restart produced no hits"
    for rid, o in zip(sorted(ref), outs):
        assert o.output == ref[rid], "warm-started output diverged"


def test_save_is_idempotent_and_reloadable(small_lm, tmp_path):
    """Adopted pages are pinned, so a warm engine can re-save its warm set
    even after every request drained (refcount never reaches zero)."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, seed=2)
    path = str(tmp_path / "warm")
    a = Engine(model, params, _conf())
    for p in prompts:
        a.submit(p, max_new_tokens=4, sampling=GREEDY, ignore_eos=True)
    a.step()
    n = a.save_prefix_cache(path)
    a.run()

    b = Engine(model, params, _conf(prefix_cache_path=path))
    b.generate(prompts, max_new_tokens=4, sampling=GREEDY, ignore_eos=True)
    path2 = str(tmp_path / "warm2")
    assert b.save_prefix_cache(path2) == n
    c = Engine(model, params, _conf(prefix_cache_path=path2))
    c.generate(prompts, max_new_tokens=4, sampling=GREEDY, ignore_eos=True)
    assert c.stats.prefix_hit_pages > 0


def test_missing_directory_is_cold_start(small_lm, tmp_path):
    cfg, model, params = small_lm
    eng = Engine(model, params,
                 _conf(prefix_cache_path=str(tmp_path / "nowhere")))
    outs = eng.generate(_prompts(cfg), max_new_tokens=4, sampling=GREEDY,
                        ignore_eos=True)
    assert all(len(o.output) == 4 for o in outs)


def test_quant_mode_mismatch_raises(small_lm, tmp_path):
    """int8 payloads+scales and bf16 payloads are different bytes for the
    same tokens — loading across quant modes must fail loudly, not serve
    garbage KV."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, seed=3)
    path = str(tmp_path / "warm")
    a = Engine(model, params, _conf())
    for p in prompts:
        a.submit(p, max_new_tokens=4, sampling=GREEDY, ignore_eos=True)
    a.step()
    a.save_prefix_cache(path)
    with pytest.raises(ValueError, match="quant mode or page size"):
        Engine(model, params, _conf(prefix_cache_path=path, kv_quant="int8"))
    # page-size mismatch is the same failure class
    with pytest.raises(ValueError, match="quant mode or page size"):
        Engine(model, params, EngineConfig(
            batch_slots=3, max_len=64, eos_id=-1, cache="paged",
            page_size=16, prefix_cache_path=path))


def test_corrupt_index_shape_raises(small_lm, tmp_path):
    cfg, model, params = small_lm
    path = str(tmp_path / "warm")
    a = Engine(model, params, _conf())
    for p in _prompts(cfg, seed=4):
        a.submit(p, max_new_tokens=4, sampling=GREEDY, ignore_eos=True)
    a.step()
    a.save_prefix_cache(path)
    idx = os.path.join(path, "index.json")
    with open(idx) as f:
        index = json.load(f)
    index["n_leaves"] += 1
    with open(idx, "w") as f:
        json.dump(index, f)
    with pytest.raises(ValueError, match="cache shape"):
        Engine(model, params, _conf(prefix_cache_path=path))


def test_slot_layout_rejects_persistence(small_lm):
    cfg, model, params = small_lm
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params, EngineConfig(
            batch_slots=2, max_len=64, eos_id=-1, cache="slot",
            prefix_cache_path="/tmp/x"))
    with pytest.raises(ValueError, match="paged"):
        eng = Engine(model, params, EngineConfig(
            batch_slots=2, max_len=64, eos_id=-1, cache="slot"))
        eng.save_prefix_cache("/tmp/x")


def test_adopted_pages_are_pinned_against_eviction(small_lm, tmp_path):
    """The warm set survives arbitrary request churn: no sequence owns the
    adopted pages, so their refcount never reaches zero and the prefix
    entries stay published."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, seed=5)
    path = str(tmp_path / "warm")
    a = Engine(model, params, _conf())
    for p in prompts:
        a.submit(p, max_new_tokens=4, sampling=GREEDY, ignore_eos=True)
    a.step()
    a.save_prefix_cache(path)
    a.run()

    b = Engine(model, params, _conf(prefix_cache_path=path))
    keys0 = set(b.pc._prefix_index)
    for _ in range(2):          # churn: admit, decode, drain, repeat
        b.generate(prompts, max_new_tokens=4, sampling=GREEDY,
                   ignore_eos=True)
    assert keys0 <= set(b.pc._prefix_index)
    hit = b.generate([prompts[0]], max_new_tokens=4, sampling=GREEDY,
                     ignore_eos=True)
    assert len(hit[0].output) == 4
