"""Quantized KV-cache subsystem tests (ISSUE 4): int8 kernel-vs-ref parity
(paged + slot attention), quantize→dequantize round-trip bounds, PagedCache
scale-pool COW/prefix invariants, engine greedy parity under int8 KV, config
validation, and byte-budget pool derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import smoke_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import flash_attention_ref, paged_attention_ref
from repro.models import build_model
from repro.models.attention import attend
from repro.perf import memory_model as MM
from repro.serving import kv_quant as KQ
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedCache
from repro.serving.kv_quant import KVQuantConfig


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# ------------------------------------------------------------------- config
def test_kv_quant_config_validation():
    assert KVQuantConfig(dtype="int8").quantized
    assert not KVQuantConfig(dtype="bf16").quantized
    # dtype aliases normalize to the canonical spelling
    assert KVQuantConfig(dtype="float32").dtype == "fp32"
    assert KVQuantConfig(dtype="bfloat16").jnp_dtype == jnp.bfloat16
    with pytest.raises(ValueError, match="dtype"):
        KVQuantConfig(dtype="int3")
    with pytest.raises(ValueError, match="granularity"):
        KVQuantConfig(granularity="tensor")
    with pytest.raises(ValueError, match="scale_dtype"):
        KVQuantConfig(scale_dtype="int8")   # fp scale pool dtype mismatch


def test_engine_config_kv_quant_validation():
    # string shorthand normalizes; unknown strings reject
    assert EngineConfig(kv_quant="int8").kv_quant == KVQuantConfig("int8")
    with pytest.raises(ValueError, match="dtype"):
        EngineConfig(kv_quant="int4")
    # quantized KV makes cache_dtype meaningless -> reject the combination
    with pytest.raises(ValueError, match="cache_dtype"):
        EngineConfig(kv_quant="int8", cache_dtype=jnp.bfloat16)
    # fp passthrough must agree with an explicit cache_dtype
    with pytest.raises(ValueError, match="conflicts"):
        EngineConfig(kv_quant="bf16", cache_dtype=jnp.float32)
    assert EngineConfig(kv_quant="bf16", cache_dtype=jnp.bfloat16)
    # the engine's fused path is per-token only
    with pytest.raises(ValueError, match="per-token"):
        EngineConfig(kv_quant=KVQuantConfig(granularity="page"))
    with pytest.raises(ValueError, match="KVQuantConfig"):
        EngineConfig(kv_quant=42)
    with pytest.raises(ValueError, match="not both"):
        EngineConfig(num_pages=4, page_pool_bytes=1 << 20)
    with pytest.raises(ValueError, match="page_pool_bytes"):
        EngineConfig(page_pool_bytes=0)


# ---------------------------------------------------------------- round-trip
@settings(max_examples=12)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 24))
def test_quantize_roundtrip_error_bound(seed, scale_pow):
    """Symmetric int8 round-trip error is bounded by scale/2 = amax/254 per
    reduction group, at any magnitude."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(5, 3, 16)) * 2.0 ** (scale_pow - 12),
                    jnp.float32)
    q, s = KQ.quantize(x)
    assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
    err = np.abs(np.asarray(KQ.dequantize(q, s)) - np.asarray(x))
    amax = np.max(np.abs(np.asarray(x)), axis=-1)
    bound = np.maximum(amax, 1e-8) / 254.0 * (1 + 1e-6)
    assert (err <= bound[..., None]).all()
    # per-page reduction obeys the same bound over its (position, D) group
    qp, sp = KQ.quantize(x, axes=(0, 2))
    errp = np.abs(np.asarray(qp.astype(jnp.float32)
                             * sp[None, :, None]) - np.asarray(x))
    amaxp = np.max(np.abs(np.asarray(x)), axis=(0, 2))
    assert (errp <= (np.maximum(amaxp, 1e-8) / 254.0
                     * (1 + 1e-6))[None, :, None]).all()


def test_quantize_zero_vector_is_exact():
    q, s = KQ.quantize(jnp.zeros((2, 3, 8)))
    assert (np.asarray(q) == 0).all()
    assert np.isfinite(np.asarray(s)).all()
    assert (np.asarray(KQ.dequantize(q, s)) == 0).all()


def test_dequantize_rejects_rank_mismatch():
    with pytest.raises(ValueError, match="rank"):
        KQ.dequantize(jnp.zeros((4, 2, 8), jnp.int8), jnp.zeros(()))


# ------------------------------------------------------------------- kernels
def _random_paged(rng, b, h, hkv, d, pages, ps, maxp, lens):
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pages, ps, hkv, d)), jnp.float32)
    bt = jnp.asarray(rng.permutation(pages)[:b * maxp].reshape(b, maxp),
                     jnp.int32)
    return q, kp, vp, bt, jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("granularity,h,hkv", [
    ("token", 8, 2), ("token", 4, 4), ("page", 8, 2)])
def test_paged_attention_int8_matches_ref(granularity, h, hkv):
    """The fused-dequant kernel path agrees with the (materializing) oracle
    at both scale granularities."""
    rng = np.random.default_rng(0)
    b, d, pages, ps, maxp = 3, 64, 17, 8, 5
    q, kp, vp, bt, lens = _random_paged(rng, b, h, hkv, d, pages, ps, maxp,
                                        [1, 11, maxp * ps])
    axes = (-1,) if granularity == "token" else (1, 3)
    kq, ks = KQ.quantize(kp, axes=axes)
    vq, vs = KQ.quantize(vp, axes=axes)
    out = paged_attention(q, kq, vq, bt, lens, k_scales=ks, v_scales=vs)
    ref = paged_attention_ref(q, kq, vq, bt, lens, k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # and the quantized result stays near the fp oracle (int8 error only)
    base = paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=0.05)


def test_paged_attention_int8_requires_both_scales():
    rng = np.random.default_rng(1)
    q, kp, vp, bt, lens = _random_paged(rng, 2, 4, 2, 32, 9, 4, 4, [7, 13])
    kq, ks = KQ.quantize(kp)
    with pytest.raises(ValueError, match="both"):
        paged_attention(q, kq, vp, bt, lens, k_scales=ks)
    with pytest.raises(ValueError, match="both"):
        paged_attention_ref(q, kq, vp, bt, lens, k_scales=ks)


def test_attend_fused_dequant_matches_flash_ref():
    """The slot-cache fused path (K scales folded into logits, V scales into
    probabilities) equals attention over the dequantized cache — decode
    (grouped) and prefill (non-grouped) branches, GQA and MHA."""
    rng = np.random.default_rng(2)
    for h, hkv, grouped, sq in [(8, 2, True, 1), (4, 4, True, 1),
                                (8, 2, False, 5)]:
        b, sk, d = 2, 12, 32
        q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
        kq, ks = KQ.quantize(k)
        vq, vs = KQ.quantize(v)
        qpos = jnp.full((b, sq), sk - sq, jnp.int32) + jnp.arange(sq)[None]
        out = attend(q, kq, vq, qpos=qpos, causal=True, grouped=grouped,
                     k_scale=ks, v_scale=vs)
        ref = flash_attention_ref(q, KQ.dequantize(kq, ks),
                                  KQ.dequantize(vq, vs), causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- PagedCache
def _quant_pc(granularity, **kw):
    kv = KVQuantConfig(dtype="int8", granularity=granularity)
    args = dict(num_pages=8, page_size=4, n_layers=2, kv_heads=2, head_dim=8,
                dtype=jnp.float32, kv_quant=kv)
    args.update(kw)
    return PagedCache(**args)


@pytest.mark.parametrize("granularity", ["token", "page"])
def test_paged_cache_quant_roundtrip_all_write_paths(granularity):
    """write_prefill + write_decode_token on an int8 pool agree with
    per-layer write_tokens, and gather_kv returns values within the int8
    round-trip bound of what was written."""
    L, n, hkv, d, ps = 2, 10, 2, 8, 4
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.normal(size=(L, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(L, n, hkv, d)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(L, hkv, d)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(L, hkv, d)), jnp.float32)

    pc = _quant_pc(granularity, n_layers=L, kv_heads=hkv, head_dim=d,
                   page_size=ps)
    assert pc.k_pages.dtype == jnp.int8 and pc.k_scales is not None
    assert pc.alloc_seq(0, n)
    pc.write_prefill(0, 0, k, v)
    assert pc.extend_seq(0, 1)
    pc.write_decode_token(0, kd, vd)

    ref = _quant_pc(granularity, n_layers=L, kv_heads=hkv, head_dim=d,
                    page_size=ps)
    assert ref.alloc_seq(0, n)
    for layer in range(L):
        ref.write_tokens(0, layer, 0, k[layer], v[layer])
    assert ref.extend_seq(0, 1)
    for layer in range(L):
        ref.write_tokens(0, layer, n, kd[layer][None], vd[layer][None])

    full_k = jnp.concatenate([k, kd[:, None]], axis=1)
    for layer in range(L):
        ka, va = pc.gather_kv(0, layer)
        kb, vb = ref.gather_kv(0, layer)
        assert ka.dtype == jnp.float32          # dequantized on read
        np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
        # round-trip bound: per-token exact scale; per-page shares one scale
        # across the page (and requantizes on append), so bound it by the
        # page amax instead
        err = np.abs(np.asarray(ka) - np.asarray(full_k[layer]))
        if granularity == "token":
            amax = np.abs(np.asarray(full_k[layer])).max(axis=-1)
            assert (err <= amax[..., None] / 254.0 * (1 + 1e-6)).all()
        else:
            assert err.max() <= np.abs(np.asarray(full_k[layer])).max() / 64.0


@pytest.mark.parametrize("granularity", ["token", "page"])
def test_paged_cache_quant_cow_copies_scales(granularity):
    """COW must copy scale-pool rows with their pages: after a follower
    rewrites shared pages, the donor's dequantized gather is bit-identical
    and the follower reads back its own values."""
    pc = _quant_pc(granularity)
    rng = np.random.default_rng(4)
    kd = jnp.asarray(rng.normal(size=(10, 2, 8)), jnp.float32)
    assert pc.alloc_seq(0, 10)
    for layer in range(2):
        pc.write_tokens(0, layer, 0, kd, kd)
    donor_table = list(pc.tables[0])
    donor_read = [np.asarray(pc.gather_kv(0, layer)[0]) for layer in range(2)]

    assert pc.alloc_seq(1, 12, share_from=0)
    kf = jnp.asarray(rng.normal(size=(12, 2, 8)) * 3.0, jnp.float32)
    for layer in range(2):
        pc.write_tokens(1, layer, 0, kf, kf)    # very different scales

    assert pc.tables[0] == donor_table
    assert pc.tables[1] != donor_table
    for layer in range(2):
        np.testing.assert_array_equal(
            np.asarray(pc.gather_kv(0, layer)[0]), donor_read[layer])
        # follower's read is its own data (not donor payloads dequantized
        # against follower scales or vice versa)
        kf_read = np.asarray(pc.gather_kv(1, layer)[0])
        amax = np.abs(np.asarray(kf)).max()
        assert np.abs(kf_read - np.asarray(kf)).max() <= amax / 60.0


def test_paged_cache_quant_prefix_reuse_shares_scales(small_lm):
    """Prefix-cache hits on an int8 pool: the follower physically shares the
    donor's quantized pages AND their scales — its gather of the shared
    prefix is bit-identical to the donor's."""
    pc = _quant_pc("token", num_pages=12)
    rng = np.random.default_rng(5)
    tokens = list(range(100, 111))              # 2 full pages + partial
    k = jnp.asarray(rng.normal(size=(11, 2, 8)), jnp.float32)
    assert pc.alloc_seq(0, 11, tokens=tokens)
    for layer in range(2):
        pc.write_tokens(0, layer, 0, k, k)
    pc.register_prefix(0, tokens)

    assert pc.alloc_seq(1, 11, tokens=tokens)
    assert pc.prefix_hits[1] == 2
    assert pc.tables[1][:2] == pc.tables[0][:2]
    for layer in range(2):
        np.testing.assert_array_equal(
            np.asarray(pc.gather_kv(1, layer)[0][:8]),
            np.asarray(pc.gather_kv(0, layer)[0][:8]))


# -------------------------------------------------------------------- engine
def _mixed_prefix_prompts(cfg, rng):
    """The mixed-length multi-request workload with a prefix-sharing pair
    from tests/test_paged.py."""
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (7, 13, 3)]
    base = rng.integers(2, cfg.vocab_size, size=8).tolist()
    prompts.append(base + rng.integers(2, cfg.vocab_size, size=5).tolist())
    prompts.append(base + rng.integers(2, cfg.vocab_size, size=3).tolist())
    return prompts


def test_engine_int8_paged_matches_bf16_slot_greedy(small_lm):
    """Acceptance: int8-KV paged decode is token-identical (greedy) to the
    bf16 slot engine on the mixed-length prefix-sharing workload; the int8
    slot engine agrees too (slot-vs-paged parity under int8 KV)."""
    cfg, model, params = small_lm
    prompts = _mixed_prefix_prompts(cfg, np.random.default_rng(0))
    engines = {
        "slot/bf16": Engine(model, params, EngineConfig(
            batch_slots=3, max_len=64, eos_id=-1, kv_quant="bf16")),
        "slot/int8": Engine(model, params, EngineConfig(
            batch_slots=3, max_len=64, eos_id=-1, kv_quant="int8")),
        "paged/int8": Engine(model, params, EngineConfig(
            batch_slots=3, max_len=64, eos_id=-1, cache="paged", page_size=4,
            kv_quant="int8")),
    }
    outs = {}
    for name, eng in engines.items():
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        outs[name] = {f.rid: f.output for f in eng.run()}
    ref = outs["slot/bf16"]
    for name in ("slot/int8", "paged/int8"):
        assert outs[name] == ref, name
    # int8 caches really were in play
    assert engines["slot/int8"].cache_dtype == jnp.int8
    paged = engines["paged/int8"]
    assert paged.stats.prefix_hit_pages > 0      # prefix sharing exercised
    leaves = {p.dtype for p in jax.tree_util.tree_leaves(paged.cache)}
    assert jnp.dtype(jnp.int8) in leaves         # payload pools
    assert paged.pc.utilization == 0.0           # everything released


def test_engine_int8_kernel_on_hot_path(small_lm, monkeypatch):
    """The int8 paged decode hot path must run the Pallas kernel with scale
    pools attached (fused dequant), not a dequantize-then-attend fallback."""
    import repro.models.attention as attn_mod
    cfg, model, params = small_lm
    seen = {"n": 0, "with_scales": 0}
    real = attn_mod.PA.paged_attention

    def counting(*a, **kw):
        seen["n"] += 1
        if kw.get("k_scales") is not None:
            seen["with_scales"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(attn_mod.PA, "paged_attention", counting)
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=32, eos_id=-1, cache="paged", page_size=4,
        kv_quant="int8"))
    eng.submit([5, 6, 7, 8, 9], max_new_tokens=3)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 3
    assert seen["n"] > 0 and seen["with_scales"] == seen["n"]


def test_engine_budget_pool_int8_doubles_pages_and_batch(small_lm):
    """Same page-pool byte budget: the int8 engine derives ~2x the bf16
    page count and sustains a deeper concurrent batch on a workload that
    exhausts the bf16 pool (the BENCH_serving capacity experiment)."""
    cfg, model, params = small_lm
    ps = 16
    budget = 4 * KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                               ps, kv_quant=KVQuantConfig(dtype="bf16"))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, cfg.vocab_size, size=28).tolist()
               for _ in range(6)]
    peaks, pages, outs = {}, {}, {}
    for mode in ("bf16", "int8"):
        eng = Engine(model, params, EngineConfig(
            batch_slots=6, max_len=128, eos_id=-1, cache="paged",
            page_size=ps, kv_quant=mode, page_pool_bytes=budget))
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        outs[mode] = {f.rid: f.output for f in eng.run()}
        peaks[mode], pages[mode] = eng.stats.peak_active, eng.pc.num_pages
    assert pages["bf16"] == 4                   # 2 pages/request -> 2 live
    assert pages["int8"] >= 2 * pages["bf16"] * 0.85
    assert peaks["int8"] > peaks["bf16"]
    assert outs["int8"] == outs["bf16"]         # greedy parity survives
    # budget must be honored: derived pool fits under it
    assert KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, ps,
                         kv_quant=KVQuantConfig(dtype="int8")) \
        * pages["int8"] <= budget


def test_engine_rejects_budget_on_slot_layout(small_lm):
    cfg, model, params = small_lm
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params, EngineConfig(
            batch_slots=2, max_len=32, cache="slot", page_pool_bytes=1 << 20))
    with pytest.raises(ValueError, match="zero pages"):
        Engine(model, params, EngineConfig(
            batch_slots=2, max_len=32, cache="paged", page_pool_bytes=8))


def test_quantized_kv_rejects_unsupported_families():
    cfg = smoke_config("falcon_mamba_7b")       # SSM: no KV to quantize
    model = build_model(cfg)
    with pytest.raises(ValueError, match="full-attention"):
        model.init_cache(2, 16, kv_quant=KVQuantConfig(dtype="int8"))
    swa = smoke_config("hymba_1p5b")            # ring buffers unsupported
    with pytest.raises(ValueError, match="full-attention|ring"):
        build_model(swa).init_cache(2, 16,
                                    kv_quant=KVQuantConfig(dtype="int8"))


# -------------------------------------------------------------- memory model
def test_kv_cache_report_capacity_factors(small_lm):
    cfg, _, _ = small_lm
    rows = MM.kv_cache_report(cfg, batch_slots=4, max_len=128, page_size=16)
    by = {(r["layout"], r["mode"]): r for r in rows}
    assert set(by) == {("slot", "fp32"), ("slot", "bf16"),
                       ("slot", "int8/token"),
                       ("paged", "fp32"), ("paged", "bf16"),
                       ("paged", "int8/token"), ("paged", "int8/page")}
    for layout in ("slot", "paged"):
        fp32 = by[(layout, "fp32")]["bytes"]
        bf16 = by[(layout, "bf16")]["bytes"]
        tok8 = by[(layout, "int8/token")]["bytes"]
        assert bf16 == fp32 / 2
        # int8+f32 per-token scales: payload/4 plus 1/head_dim overhead
        assert fp32 / 4 < tok8 < fp32 / 2
        assert by[(layout, "int8/token")]["capacity_x_vs_fp32"] > 3.0
    # per-page scales are cheaper than per-token
    assert (by[("paged", "int8/page")]["bytes"]
            < by[("paged", "int8/token")]["bytes"])
    # the report matches the byte-budget derivation the engine uses
    per_page = KQ.page_bytes(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                             16, kv_quant=KVQuantConfig(dtype="int8"))
    assert KQ.num_pages_for_budget(per_page * 5, cfg.num_layers,
                                   cfg.num_kv_heads, cfg.head_dim, 16,
                                   kv_quant=KVQuantConfig(dtype="int8")) == 5
