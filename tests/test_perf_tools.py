"""perf substrate tests: while-aware HLO cost parser (exactness on known
programs), roofline term assembly, collective wire-cost formulas, analytic
memory model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf import hlo_cost as H
from repro.perf import roofline as R


def test_scan_flops_exact():
    def scanned(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    c = jax.jit(scanned).lower(ws, x).compile()
    cost = H.analyze_text(c.as_text(), 1)
    assert cost.flops == 8 * 2 * 32 * 256 * 256
    assert cost.n_while == 1 and cost.max_trip == 8


def test_nested_scan_multiplies():
    def inner(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    def outer(ws, x):
        def body(c, w):
            return inner(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    c = jax.jit(outer).lower(ws, x).compile()
    cost = H.analyze_text(c.as_text(), 1)
    assert cost.flops == 4 * 3 * 2 * 8 * 64 * 64


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    assert H.analyze_text(c.as_text(), 1).flops == 2 * 4 * 32 * 64 * 16


def test_shape_bytes():
    assert H.shape_bytes("f32[4,8]") == 128
    assert H.shape_bytes("bf16[10]{0}") == 20
    assert H.shape_bytes("(f32[2,2], s32[3])") == 28
    assert H.shape_bytes("pred[5]") == 5


def test_wire_cost_formulas():
    op = H.Op("x", "f32[1000]", "all-reduce", ["a"],
              "= f32[1000] all-reduce(%a), replica_groups=[4,8]<=[32]")
    assert H._wire_bytes(op, 32) == pytest.approx(2 * 4000 * 7 / 8)
    op2 = H.Op("x", "f32[1000]", "all-gather", ["a"],
               "= f32[1000] all-gather(%a), replica_groups=[4,8]<=[32]")
    assert H._wire_bytes(op2, 32) == pytest.approx(4000 * 7 / 8)


def test_dus_cache_write_not_charged_full_buffer():
    """In-place cache update inside scan must charge ~update bytes, not the
    full cache (decode memory-term correctness)."""
    def step(cache, new):
        def body(c, n):
            c = jax.lax.dynamic_update_slice(c, n[None, None], (0, 5, 0))
            return c, jnp.sum(n)
        c2, s = jax.lax.scan(body, cache, new)
        return c2, s

    cache = jax.ShapeDtypeStruct((1, 1024, 64), jnp.float32)
    new = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    c = jax.jit(step).lower(cache, new).compile()
    cost = H.analyze_text(c.as_text(), 1)
    full = 4 * (1024 * 64 * 4)          # 4 iterations x full cache
    assert cost.hbm_bytes < full, (cost.hbm_bytes, full)


def test_roofline_dominant_and_ratio():
    def f(a, b):
        return (a @ b).sum()
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    roof = R.analyze(c, n_devices=1, model_flops_global=2 * 512**3)
    assert roof.dominant in ("compute", "memory")
    assert 0.5 < roof.useful_ratio <= 1.5
    assert roof.flops_per_dev == pytest.approx(2 * 512**3, rel=0.01)


def test_memory_model_sharded_bytes():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.perf.memory_model import sharded_state_bytes
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_mesh((1,), ("model",))
    tree = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    assert sharded_state_bytes(tree, sh, mesh) == 64 * 32 * 4
