"""Flash attention Pallas kernel vs the jnp reference oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape)
                       .astype(np.float32)) * 0.5


@pytest.mark.parametrize("b,s,h,d,bq,bk", [
    (1, 128, 2, 32, 64, 64),
    (2, 256, 4, 64, 128, 128),
    (1, 64, 1, 16, 64, 32),     # single q block, several k blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(b, s, h, d, bq, bk, causal):
    q, k, v = (_rand((b, s, h, d), i) for i in range(3))
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_bf16():
    q, k, v = (_rand((1, 128, 2, 32), i).astype(jnp.bfloat16) for i in range(3))
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2)


def test_flash_long_kv_decode_like():
    # Sq << Sk (chunked prefill tail), non-causal to exercise full K span
    q = _rand((1, 64, 2, 32), 5)
    k = _rand((1, 512, 2, 32), 6)
    v = _rand((1, 512, 2, 32), 7)
    out = flash_attention(q, k, v, causal=False, bq=64, bk=128)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
