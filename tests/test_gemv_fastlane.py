"""Decode fast-lane tests (ISSUE 1): fused GEMV kernel parity vs the jnp
oracle across every strategy x group size x odd M, the M-threshold dispatcher,
fused bias, and non-divisible-shape robustness of the general kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gptq
from repro.core.opt_strategies import STRATEGIES, get_strategy
from repro.kernels import gptq_gemv
from repro.kernels import gptq_matmul as gm
from repro.kernels import ops


def _make_quant(k, n, g, seed=0, bias=False):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.5, size=(k, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) if bias else None
    return gptq.gptq_quantize(w, None, gptq.GPTQConfig(group_size=g), bias=b)


@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("g", [64, 128, -1])
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_gemv_matches_oracle(strategy, g, m):
    """All six ablation variants x group sizes {64, 128, per-column} x odd M."""
    k, n = 128, 64
    ql = _make_quant(k, n, g, seed=(g % 7) * 10 + m)
    x = jnp.asarray(
        np.random.default_rng(m).normal(size=(m, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    # M <= GEMV_M_MAX routes through the GEMV lane inside gptq_linear
    y = ops.gptq_linear(ql, x, strategy=get_strategy(strategy),
                        use_pallas=True, block_sizes=(8, 64, 64))
    atol = 1e-1 if strategy == "naive" else 2e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=atol)


def test_dispatcher_routes_by_m(monkeypatch):
    """Decode-shaped M goes to the GEMV lane, prefill M to the tiled matmul."""
    calls = {"gemv": 0, "matmul": 0}
    real_gemv, real_mm = gptq_gemv.gptq_gemv, gm.gptq_matmul

    def spy_gemv(*a, **k):
        calls["gemv"] += 1
        return real_gemv(*a, **k)

    def spy_mm(*a, **k):
        calls["matmul"] += 1
        return real_mm(*a, **k)

    monkeypatch.setattr(ops._gemv, "gptq_gemv", spy_gemv)
    monkeypatch.setattr(ops._gm, "gptq_matmul", spy_mm)
    ql = _make_quant(128, 64, 64, seed=1)
    x_small = jnp.ones((gptq_gemv.GEMV_M_MAX, 128), jnp.float32)
    x_large = jnp.ones((gptq_gemv.GEMV_M_MAX + 1, 128), jnp.float32)
    ops.gptq_linear(ql, x_small, use_pallas=True, block_sizes=(8, 64, 64))
    assert calls == {"gemv": 1, "matmul": 0}
    ops.gptq_linear(ql, x_large, use_pallas=True, block_sizes=(16, 64, 64))
    assert calls == {"gemv": 1, "matmul": 1}


def test_gemv_fused_bias():
    k, n, m = 128, 64, 4
    ql = _make_quant(k, n, 64, seed=5, bias=True)
    x = jnp.asarray(
        np.random.default_rng(5).normal(size=(m, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y = ops.gptq_linear(ql, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


def test_gemv_leading_batch_dims():
    k, n = 128, 64
    ql = _make_quant(k, n, 64, seed=6)
    x = jnp.asarray(
        np.random.default_rng(6).normal(size=(2, 3, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y = ops.gptq_linear(ql, x, use_pallas=True)      # 2*3 = 6 rows -> GEMV
    assert y.shape == (2, 3, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


# ------------------------------------------------- shape robustness (general)
def test_matmul_pads_non_divisible_n():
    """N=1016 with the default bn=256 used to hit a bare assert; now pads."""
    k, n = 128, 1016
    ql = _make_quant(k, n, 64, seed=7)
    x = jnp.asarray(
        np.random.default_rng(7).normal(size=(16, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y = ops.gptq_linear(ql, x, use_pallas=True)      # default block sizes
    assert y.shape == (16, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


def test_matmul_shrinks_non_divisible_bk():
    """K=320 with requested bk=512 shrinks to a legal divisor, no crash."""
    k, n = 320, 64
    ql = _make_quant(k, n, 64, seed=8)
    x = jnp.asarray(
        np.random.default_rng(8).normal(size=(16, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y = ops.gptq_linear(ql, x, use_pallas=True, block_sizes=(16, 64, 512))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


def test_resolve_block_sizes_unservable_raises():
    with pytest.raises(ValueError, match="K=12"):
        gm.resolve_block_sizes(1, 12, 64, 12, 8, 64, 64)
    with pytest.raises(ValueError, match="N=60"):
        gm.pad_cols(jnp.zeros((16, 60), jnp.int32), jnp.ones((2, 60)),
                    jnp.zeros((2, 7), jnp.int32), 60, 64)
