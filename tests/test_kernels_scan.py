"""Selective-scan Pallas kernel vs the jnp oracle (shape/dtype sweeps +
state-carry chunked-prefill equivalence)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import selective_scan_ref
from repro.kernels.selective_scan import selective_scan


def _inputs(b, l, di, s, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(0, 0.5, (b, l, di)).astype(np.float32))
    dt = jnp.asarray(r.normal(-1.0, 0.3, (b, l, di)).astype(np.float32))
    a = -jnp.asarray(np.abs(r.normal(1.0, 0.3, (di, s))).astype(np.float32))
    bb = jnp.asarray(r.normal(0, 0.5, (b, l, s)).astype(np.float32))
    c = jnp.asarray(r.normal(0, 0.5, (b, l, s)).astype(np.float32))
    d = jnp.asarray(r.normal(0, 0.5, (di,)).astype(np.float32))
    return x, dt, a, bb, c, d


@pytest.mark.parametrize("b,l,di,s,bd,bl", [
    (1, 32, 16, 4, 16, 16),
    (2, 64, 32, 8, 16, 32),
    (1, 16, 8, 16, 8, 16),     # single L block
])
def test_scan_kernel_matches_ref(b, l, di, s, bd, bl):
    x, dt, a, bb, c, d = _inputs(b, l, di, s, seed=l)
    y_ref, h_ref = selective_scan_ref(x, dt, a, bb, c, d)
    y_k, h_k = selective_scan(x, dt, a, bb, c, d, bd=bd, bl=bl)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_scan_kernel_state_carry_chunked():
    """Running two chunks with carried state == one full pass (the chunked
    prefill contract)."""
    x, dt, a, bb, c, d = _inputs(1, 64, 16, 4, seed=9)
    y_full, h_full = selective_scan(x, dt, a, bb, c, d, bd=16, bl=32)
    y1, h1 = selective_scan(x[:, :32], dt[:, :32], a, bb[:, :32], c[:, :32], d,
                            bd=16, bl=32)
    y2, h2 = selective_scan(x[:, 32:], dt[:, 32:], a, bb[:, 32:], c[:, 32:], d,
                            h0=h1, bd=16, bl=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_scan_kernel_bf16_inputs():
    x, dt, a, bb, c, d = _inputs(1, 32, 16, 4, seed=3)
    y_ref, _ = selective_scan_ref(x, dt, a, bb, c, d)
    y_k, _ = selective_scan(x.astype(jnp.bfloat16), dt.astype(jnp.bfloat16),
                            a, bb.astype(jnp.bfloat16), c.astype(jnp.bfloat16),
                            d, bd=16, bl=32)
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_ref),
                               rtol=5e-2, atol=5e-2)
