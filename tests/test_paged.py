"""Paged-KV serving subsystem tests (ISSUE 2): Pallas paged-attention kernel
vs oracle, PagedCache copy-on-write / prefix-cache / free-list invariants,
and Engine(cache="paged") parity with the slot engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import smoke_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import flash_attention_ref, paged_attention_ref
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import DEFAULT_CACHE_DTYPE, PagedCache


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# ------------------------------------------------------------------- kernel
def _random_paged(rng, b, h, hkv, d, pages, ps, maxp, lens):
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pages, ps, hkv, d)), jnp.float32)
    bt = jnp.asarray(rng.permutation(pages)[:b * maxp].reshape(b, maxp),
                     jnp.int32)
    return q, kp, vp, bt, jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("h,hkv", [(8, 2), (4, 4)])
def test_paged_attention_matches_ref(h, hkv):
    rng = np.random.default_rng(0)
    b, d, pages, ps, maxp = 3, 64, 17, 8, 5
    q, kp, vp, bt, lens = _random_paged(rng, b, h, hkv, d, pages, ps, maxp,
                                        [1, 11, maxp * ps])
    out = paged_attention(q, kp, vp, bt, lens)
    ref = paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_matches_contiguous_flash_ref():
    """Gathering each sequence's pages into a contiguous cache and running
    plain masked attention must agree with the block-table kernel."""
    rng = np.random.default_rng(1)
    b, h, hkv, d, pages, ps, maxp = 2, 8, 2, 32, 11, 4, 4
    q, kp, vp, bt, lens = _random_paged(rng, b, h, hkv, d, pages, ps, maxp,
                                        [7, 13])
    out = paged_attention(q, kp, vp, bt, lens)
    for i in range(b):
        L = int(lens[i])
        kc = kp[bt[i]].reshape(-1, hkv, d)[:L][None]
        vc = vp[bt[i]].reshape(-1, hkv, d)[:L][None]
        ref = flash_attention_ref(q[i][None, None], kc, vc, causal=True)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref[0, 0]),
                                   rtol=2e-5, atol=2e-5)


def test_paged_attention_ignores_pages_past_length():
    """Block-table padding (null page) and garbage in unowned pages must not
    leak into the output: clobbering every page past each sequence's length
    with huge values leaves the result unchanged."""
    rng = np.random.default_rng(2)
    b, h, hkv, d, pages, ps, maxp = 2, 4, 2, 16, 9, 4, 4
    q, kp, vp, bt, lens = _random_paged(rng, b, h, hkv, d, pages, ps, maxp,
                                        [5, 9])
    out = paged_attention(q, kp, vp, bt, lens)
    used = {int(bt[i, j]) for i in range(b)
            for j in range(-(-int(lens[i]) // ps))}
    clobber = [p for p in range(pages) if p not in used]
    kp2 = kp.at[jnp.asarray(clobber)].set(1e9)
    vp2 = vp.at[jnp.asarray(clobber)].set(-1e9)
    out2 = paged_attention(q, kp2, vp2, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2))


# ---------------------------------------------------------------- PagedCache
def test_paged_cache_cow_protects_donor():
    """Regression (seed bug): a follower sharing a donor's pages then writing
    past the shared prefix silently corrupted the donor's KV.  With
    copy-on-write the donor's gather is bit-identical after the follower
    overwrites every shared position."""
    pc = PagedCache(num_pages=8, page_size=4, n_layers=2, kv_heads=1,
                    head_dim=4, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    kd = jnp.asarray(rng.normal(size=(10, 1, 4)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(10, 1, 4)), jnp.float32)
    assert pc.alloc_seq(0, 10)                     # 3 pages, last partial
    for layer in range(2):
        pc.write_tokens(0, layer, 0, kd, vd)
    donor_table = list(pc.tables[0])

    # follower shares all 3 pages (incl. the donor's partial last page),
    # then writes its own 12 tokens over [0, 12)
    assert pc.alloc_seq(1, 12, share_from=0)
    kf = jnp.asarray(rng.normal(size=(12, 1, 4)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(12, 1, 4)), jnp.float32)
    for layer in range(2):
        pc.write_tokens(1, layer, 0, kf, vf)

    assert pc.tables[1] != donor_table             # COW re-pointed the writes
    assert pc.tables[0] == donor_table             # donor untouched
    for layer in range(2):
        k0, v0 = pc.gather_kv(0, layer)
        np.testing.assert_array_equal(np.asarray(k0), np.asarray(kd))
        np.testing.assert_array_equal(np.asarray(v0), np.asarray(vd))
        k1, v1 = pc.gather_kv(1, layer)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(kf))
    # refcounts dropped back to exclusive ownership everywhere
    for p in donor_table:
        assert pc.refcount[p] == 1


def test_paged_cache_partial_cow_keeps_untouched_pages_shared():
    """Writing only the divergent suffix copies just the pages it touches:
    the untouched prefix pages stay physically shared (refcount 2)."""
    pc = PagedCache(num_pages=8, page_size=4, n_layers=1, kv_heads=1,
                    head_dim=4, dtype=jnp.float32)
    rng = np.random.default_rng(1)
    kd = jnp.asarray(rng.normal(size=(12, 1, 4)), jnp.float32)
    assert pc.alloc_seq(0, 12)                     # 3 full pages
    pc.write_tokens(0, 0, 0, kd, kd)
    donor_table = list(pc.tables[0])
    assert pc.alloc_seq(1, 12, share_from=0)       # shares all 3
    # divergent suffix only: positions [8, 12) live in shared page 2
    kf = jnp.asarray(rng.normal(size=(4, 1, 4)), jnp.float32)
    pc.write_tokens(1, 0, 8, kf, kf)
    assert pc.tables[1][:2] == donor_table[:2]     # prefix still shared
    assert pc.tables[1][2] != donor_table[2]       # suffix page COW'd
    assert pc.refcount[donor_table[0]] == 2
    assert pc.refcount[donor_table[2]] == 1
    np.testing.assert_array_equal(np.asarray(pc.gather_kv(0, 0)[0]),
                                  np.asarray(kd))
    np.testing.assert_array_equal(np.asarray(pc.gather_kv(1, 0)[0][8:]),
                                  np.asarray(kf))


def test_paged_cache_write_tokens_is_batched(monkeypatch):
    """write_tokens must dispatch one scatter per pool per call, not one per
    token (the seed's O(n) loop): count `.at` indexed-update dispatches."""
    pc = PagedCache(num_pages=8, page_size=4, n_layers=1, kv_heads=2,
                    head_dim=4, dtype=jnp.float32)
    assert pc.alloc_seq(0, 14)
    arr_cls = type(pc.k_pages)
    orig = arr_cls.at
    calls = {"n": 0}

    class CountingAt:
        def __get__(self, obj, objtype=None):
            calls["n"] += 1
            return orig.__get__(obj, objtype)

    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(14, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(14, 2, 4)), jnp.float32)
    monkeypatch.setattr(arr_cls, "at", CountingAt())
    pc.write_tokens(0, 0, 0, k, v)
    monkeypatch.undo()
    assert calls["n"] == 2                     # one per pool (k, v)
    k2, v2 = pc.gather_kv(0, 0)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v), rtol=1e-6)


def test_paged_cache_all_layer_write_paths():
    """Standalone data-path API: ``write_prefill`` (all layers, one scatter
    per pool) and ``write_decode_token`` (one fused scatter for the decode
    token) agree with per-layer ``write_tokens``."""
    L, n, hkv, d, ps = 3, 10, 2, 4, 4
    rng = np.random.default_rng(2)
    k = jnp.asarray(rng.normal(size=(L, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(L, n, hkv, d)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(L, hkv, d)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(L, hkv, d)), jnp.float32)

    pc = PagedCache(num_pages=8, page_size=ps, n_layers=L, kv_heads=hkv,
                    head_dim=d, dtype=jnp.float32)
    assert pc.alloc_seq(0, n)
    pc.write_prefill(0, 0, k, v)
    assert pc.extend_seq(0, 1)
    pc.write_decode_token(0, kd, vd)

    ref = PagedCache(num_pages=8, page_size=ps, n_layers=L, kv_heads=hkv,
                     head_dim=d, dtype=jnp.float32)
    assert ref.alloc_seq(0, n)
    for layer in range(L):
        ref.write_tokens(0, layer, 0, k[layer], v[layer])
    assert ref.extend_seq(0, 1)
    for layer in range(L):
        ref.write_tokens(0, layer, n, kd[layer][None], vd[layer][None])

    for layer in range(L):
        ka, va = pc.gather_kv(0, layer)
        kb, vb = ref.gather_kv(0, layer)
        np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


def test_paged_cache_prefix_cache_reuse_and_eviction():
    ps = 4
    pc = PagedCache(num_pages=8, page_size=ps, n_layers=1, kv_heads=1,
                    head_dim=4, dtype=jnp.float32)
    tokens = list(range(100, 111))                 # 11 tokens: 2 full pages
    assert pc.alloc_seq(0, len(tokens), tokens=tokens)
    assert pc.prefix_hits[0] == 0                  # cold cache
    pc.register_prefix(0, tokens)

    assert pc.alloc_seq(1, len(tokens), tokens=tokens)
    assert pc.prefix_hits[1] == 2                  # both full pages reused
    assert pc.tables[1][:2] == pc.tables[0][:2]    # physically shared
    assert pc.tables[1][2] != pc.tables[0][2]      # private partial page

    pc.free_seq(0)                                 # follower keeps pages alive
    assert all(pc.refcount[p] == 1 for p in pc.tables[1][:2])
    pc.free_seq(1)
    assert pc.utilization == 0.0
    # eviction: freed pages left the index; a fresh alloc sees a cold cache
    assert pc.alloc_seq(2, len(tokens), tokens=tokens)
    assert pc.prefix_hits[2] == 0


def test_paged_cache_block_table_device_sync():
    pc = PagedCache(num_pages=8, page_size=4, n_layers=1, kv_heads=1,
                    head_dim=4)
    assert pc.alloc_seq(5, 9)
    row = pc.row_of(5)
    bt = np.asarray(pc.block_tables[row])
    assert list(bt[:3]) == pc.tables[5]
    assert (bt[3:] == 0).all()                     # padding -> null page
    assert pc.extend_seq(5, 4)                     # crosses a page boundary
    assert list(np.asarray(pc.block_tables[row])[:4]) == pc.tables[5]
    pc.free_seq(5)
    assert (np.asarray(pc.block_tables[row]) == 0).all()


@settings(max_examples=12)
@given(st.integers(0, 2 ** 31 - 1))
def test_paged_cache_free_list_invariants(seed):
    """Randomized alloc/extend/free/share sequences keep the manager sane:
    refcounts count exactly the table references, the free list is disjoint
    from live pages, and every page is either free or referenced."""
    rng = np.random.default_rng(seed)
    pc = PagedCache(num_pages=12, page_size=4, n_layers=1, kv_heads=1,
                    head_dim=4)
    next_id = 0
    for _ in range(40):
        op = rng.integers(0, 4)
        live = list(pc.tables)
        if op == 0 or not live:
            share = int(rng.choice(live)) if live and rng.integers(2) else None
            pc.alloc_seq(next_id, int(rng.integers(1, 20)), share_from=share)
            next_id += 1
        elif op == 1:
            pc.extend_seq(int(rng.choice(live)), int(rng.integers(1, 6)))
        elif op == 2:
            pc.free_seq(int(rng.choice(live)))
        else:
            sid = int(rng.choice(live))
            n = pc.lengths[sid]
            k = jnp.zeros((n, 1, 4), jnp.float32)
            try:
                pc.write_tokens(sid, 0, 0, k, k)   # may trigger COW
            except RuntimeError:
                pass                               # COW with an empty pool
        refs = {}
        for t in pc.tables.values():
            for p in t:
                refs[p] = refs.get(p, 0) + 1
        assert 0 not in refs                       # null page never allocated
        for p, n in refs.items():
            assert pc.refcount[p] == n, (p, n, pc.refcount[p])
        assert set(pc.free_list).isdisjoint(refs)
        assert len(pc.free_list) + len(refs) == pc.num_pages
        assert 0.0 <= pc.utilization <= 1.0
        for sid, t in pc.tables.items():
            row_bt = np.asarray(pc.block_tables[pc.row_of(sid)])
            assert list(row_bt[:len(t)]) == t


# -------------------------------------------------------------- paged engine
def test_engine_paged_matches_slot_greedy(small_lm):
    """Greedy outputs of the paged engine are token-identical to the slot
    engine over a mixed-length multi-request queue that includes a
    prefix-sharing pair; the pair produces nonzero prefix-hit stats."""
    cfg, model, params = small_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (7, 13, 3)]
    base = rng.integers(2, cfg.vocab_size, size=8).tolist()  # 2 full pages
    prompts.append(base + rng.integers(2, cfg.vocab_size, size=5).tolist())
    prompts.append(base + rng.integers(2, cfg.vocab_size, size=3).tolist())

    eng_s = Engine(model, params, batch_slots=3, max_len=64, eos_id=-1)
    eng_p = Engine(model, params, batch_slots=3, max_len=64, eos_id=-1,
                   cache="paged", page_size=4)
    for p in prompts:
        eng_s.submit(p, max_new_tokens=4)
        eng_p.submit(p, max_new_tokens=4)
    done_s = {f.rid: f.output for f in eng_s.run()}
    done_p = {f.rid: f.output for f in eng_p.run()}
    assert done_s.keys() == done_p.keys()
    for rid in done_s:
        assert done_s[rid] == done_p[rid], rid
    assert eng_p.stats.prefix_hit_pages > 0
    assert eng_p.stats.prefix_hit_tokens == \
        eng_p.stats.prefix_hit_pages * eng_p.pc.page_size
    assert eng_p.pc.utilization == 0.0             # everything released


def test_engine_paged_kernel_on_hot_path(small_lm, monkeypatch):
    """The decode hot path must run the Pallas paged-attention kernel, not
    the jnp gather reference."""
    import repro.models.attention as attn_mod
    cfg, model, params = small_lm
    calls = {"n": 0}
    real = attn_mod.PA.paged_attention

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(attn_mod.PA, "paged_attention", counting)
    eng = Engine(model, params, batch_slots=2, max_len=32, eos_id=-1,
                 cache="paged", page_size=4)
    eng.submit([5, 6, 7, 8, 9], max_new_tokens=3)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 3
    assert calls["n"] > 0                          # kernel traced on decode


def test_engine_paged_exhaustion_defers_admission(small_lm):
    """A queue whose working set exceeds the page pool drains completely —
    admission defers until pages free up instead of crashing."""
    cfg, model, params = small_lm
    # pool of 8 pages x 4 tokens; each request reserves 3 pages -> at most 2
    # concurrent, queue of 6
    eng = Engine(model, params, batch_slots=4, max_len=32, eos_id=-1,
                 cache="paged", page_size=4, num_pages=8)
    rng = np.random.default_rng(3)
    for _ in range(6):
        eng.submit(rng.integers(2, cfg.vocab_size, size=7).tolist(),
                   max_new_tokens=3)
    max_active = 0
    done = []
    for _ in range(200):
        done.extend(eng.step())
        max_active = max(max_active, len(eng.sched.active))
        if eng.sched.idle:
            break
    assert len(done) == 6
    assert max_active <= 2                         # page budget enforced
    assert eng.pc.utilization == 0.0


def test_engine_paged_rejects_impossible_request(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=2, max_len=32, eos_id=-1,
                 cache="paged", page_size=4, num_pages=4)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(2, 30)), max_new_tokens=8)


def test_engine_paged_admits_beyond_slot_reservation(small_lm):
    """The paged pool admits a workload whose summed prompt lengths exceed
    the slot layout's batch_slots x max_len worst-case reservation, using
    half the slot cache's token memory."""
    cfg, model, params = small_lm
    batch_slots, max_len = 2, 64
    eng = Engine(model, params, batch_slots=batch_slots, max_len=max_len,
                 eos_id=-1, cache="paged", page_size=4, num_pages=16)
    assert eng.pc.num_pages * eng.pc.page_size < batch_slots * max_len
    rng = np.random.default_rng(4)
    prompts = [rng.integers(2, cfg.vocab_size, size=24).tolist()
               for _ in range(6)]
    assert sum(map(len, prompts)) > batch_slots * max_len
    for p in prompts:
        eng.submit(p, max_new_tokens=3)
    done = eng.run()
    assert len(done) == 6
    assert all(len(f.output) == 3 for f in done)


def test_engine_paged_prefill_recompiles_are_bucketed(small_lm, monkeypatch):
    """Distinct prompt lengths inside one step-width bucket share a single
    fused-step trace (the padded positions' writes go to the null page) —
    the paged path must not recompile per exact chunk length."""
    cfg, model, params = small_lm
    traces = {"n": 0}
    orig = Engine._fused_step_impl

    def counting(*args, **kwargs):
        traces["n"] += 1                       # runs once per jit trace
        return orig(*args, **kwargs)

    monkeypatch.setattr(Engine, "_fused_step_impl", staticmethod(counting))
    eng = Engine(model, params, batch_slots=4, max_len=64, eos_id=-1,
                 cache="paged", page_size=4)
    rng = np.random.default_rng(6)
    outs = {}
    for n in (3, 7, 12, 9):                    # all within the 32 bucket
        rid = eng.submit(rng.integers(2, cfg.vocab_size, size=n).tolist(),
                         max_new_tokens=3)
        outs[rid] = n
    done = eng.run()
    assert len(done) == 4
    # one trace for the width-32 prefill step, one for width-1 decode steps
    assert traces["n"] == 2, traces["n"]

    # parity against the slot engine for the same bucketed workload
    eng_s = Engine(model, params, batch_slots=4, max_len=64, eos_id=-1)
    rng = np.random.default_rng(6)
    for n in (3, 7, 12, 9):
        eng_s.submit(rng.integers(2, cfg.vocab_size, size=n).tolist(),
                     max_new_tokens=3)
    done_s = {f.rid: f.output for f in eng_s.run()}
    for f in done:
        assert f.output == done_s[f.rid], f.rid


def test_engine_paged_mixed_sampling(small_lm):
    from repro.serving.sampler import SamplingParams
    cfg, model, params = small_lm
    eng = Engine(model, params, batch_slots=3, max_len=64, eos_id=-1,
                 cache="paged", page_size=4)
    rng = np.random.default_rng(5)
    rids = [
        eng.submit(rng.integers(2, cfg.vocab_size, size=6).tolist(),
                   max_new_tokens=4, sampling=sp)
        for sp in (SamplingParams(greedy=True),
                   SamplingParams(temperature=0.7, top_k=3),
                   SamplingParams(temperature=1.1, top_p=0.8))]
    done = eng.run()
    assert sorted(f.rid for f in done) == sorted(rids)
    for f in done:
        assert len(f.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in f.output)


# ------------------------------------------------------------------ dtypes
def test_cache_dtype_single_source_and_respected(small_lm):
    cfg, model, params = small_lm
    # default flows from DEFAULT_CACHE_DTYPE in both layouts
    eng = Engine(model, params, batch_slots=1, max_len=16, eos_id=-1)
    leaf = jax.tree_util.tree_leaves(eng.slots.cache)[0]
    assert leaf.dtype == DEFAULT_CACHE_DTYPE
    engp = Engine(model, params, batch_slots=1, max_len=16, eos_id=-1,
                  cache="paged", page_size=4)
    leafp = jax.tree_util.tree_leaves(engp.cache)[0]
    assert leafp.dtype == DEFAULT_CACHE_DTYPE
    assert PagedCache(num_pages=2, page_size=2, n_layers=1, kv_heads=1,
                      head_dim=2).k_pages.dtype == DEFAULT_CACHE_DTYPE
    # and an explicit override is respected in both layouts
    eng16 = Engine(model, params, batch_slots=1, max_len=16, eos_id=-1,
                   cache_dtype=jnp.bfloat16)
    assert jax.tree_util.tree_leaves(eng16.slots.cache)[0].dtype == jnp.bfloat16
    engp16 = Engine(model, params, batch_slots=1, max_len=16, eos_id=-1,
                    cache="paged", page_size=4, cache_dtype=jnp.bfloat16)
    assert jax.tree_util.tree_leaves(engp16.cache)[0].dtype == jnp.bfloat16
