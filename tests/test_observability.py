"""Observability-layer tests (ISSUE 7 / DESIGN.md §15): the typed metrics
registry (counters/gauges/histograms, Prometheus text exposition round
trip), the step-span tracer (byte-deterministic Perfetto export under a
ManualClock, schema validation), the HTTP ``/metrics`` + ``/healthz``
surface, exact /metrics-vs-EngineStats agreement after a mixed workload,
counter/span accounting under preemption + injected faults, clock-driven
``wall_s``, and the zero-cost guarantees: greedy outputs identical with
observability on or off, and still exactly one device->host transfer per
decode step."""
import json
import threading
import urllib.error
import urllib.request

import jax
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import faults as F
from repro.serving import metrics as M
from repro.serving.api import EngineConfig, FinishReason
from repro.serving.clock import ManualClock
from repro.serving.engine import Engine
from repro.serving.http_api import make_server
from repro.serving.sampler import SamplingParams
from repro.serving.tracing import (PID_ENGINE, PID_REQUESTS, Tracer,
                                   validate_trace)
from tests.test_serving_faults import _drain, _prompts

GREEDY = SamplingParams(greedy=True)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# ------------------------------------------------------------------- registry
def test_counter_gauge_histogram_basics():
    r = M.MetricsRegistry()
    c = r.counter("c_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g", "a gauge")
    g.set(5)
    g.dec(2)
    g.set_max(2)                    # below current -> no-op
    assert g.value == 3.0
    g.set_max(9)
    assert g.value == 9.0
    h = r.histogram("h_seconds", "a histogram", (0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 100.0):
        h.observe(v)
    assert h.total_count == 5 and h.total_sum == pytest.approx(106.05)
    # p50 of 5 samples lands in the (0.1, 1.0] bucket, interpolated
    assert 0.1 < h.quantile(0.5) <= 1.0
    # top quantile falls in +Inf bucket -> clamped to the last finite bound
    assert h.quantile(0.99) == 10.0


def test_histogram_needs_buckets_and_reregistration_consistency():
    r = M.MetricsRegistry()
    with pytest.raises(ValueError, match="bucket"):
        r.histogram("h", "no buckets", ())
    r.counter("x_total", "x")
    assert r.counter("x_total", "x").value == 0.0   # same schema: same family
    with pytest.raises(ValueError, match="re-registered"):
        r.gauge("x_total", "now a gauge")


def test_labeled_family_children_and_zero_label_guard():
    r = M.MetricsRegistry()
    c = r.counter("req_total", "by reason", labels=("reason",))
    c.labels(reason="stop").inc(2)
    c.labels(reason="abort").inc()
    assert c.value == 3.0                      # aggregate across children
    with pytest.raises(ValueError, match="labels"):
        c.inc()                                # labeled family needs .labels
    with pytest.raises(ValueError, match="takes labels"):
        c.labels(nope="x")


def test_exposition_round_trip_with_const_labels():
    r = M.MetricsRegistry(const_labels={"layout": "paged", "kv_quant": "int8"})
    r.counter("t_total", "tokens").inc(7)
    h = r.histogram("lat_seconds", "latency", (0.5, 2.0), labels=("prio",))
    h.labels(prio=0).observe(0.3)
    h.labels(prio=1).observe(1.0)
    text = r.expose()
    parsed = M.parse_prometheus_text(text)
    assert parsed["t_total"]["type"] == "counter"
    (_, labels, value), = parsed["t_total"]["samples"]
    assert labels == {"layout": "paged", "kv_quant": "int8"} and value == 7.0
    # histogram: cumulative buckets + _sum/_count per child
    names = [n for n, _, _ in parsed["lat_seconds"]["samples"]]
    assert names.count("lat_seconds_bucket") == 6    # 2 children x 3 buckets
    assert names.count("lat_seconds_count") == 2
    infs = [(lab, v) for n, lab, v in parsed["lat_seconds"]["samples"]
            if lab.get("le") == "+Inf"]
    assert all(v == 1.0 for _, v in infs)
    with pytest.raises(ValueError):
        M.parse_prometheus_text("garbage_without_type 1.0")


def test_null_registry_is_inert():
    m = M.make_engine_metrics("slot", "fp32", enabled=False)
    m.tokens_generated.inc(100)
    m.ttft.labels(priority=1).observe(3.0)
    m.peak_active.set_max(5)
    assert m.tokens_generated.value == 0.0
    assert m.ttft.quantile(0.99) == 0.0
    assert m.registry.expose() == ""
    assert m.registry.snapshot()["families"] == {}


# -------------------------------------------------------------------- tracer
def test_tracer_spans_and_validation():
    tr = Tracer()
    tr.request_state(3, "QUEUED", 1.0, prompt_len=4)
    tr.request_state(3, "RUNNING", 2.0)
    tr.step_span(2.0, 2.5, step=0, batch=1)
    tr.fault_instant("stall", 2.25)
    tr.request_end(3, "stop", 4.0, tokens=6)
    d = tr.to_dict()
    assert validate_trace(d) == []
    evs = d["traceEvents"]
    queued = next(e for e in evs if e["name"] == "QUEUED")
    assert queued == {"name": "QUEUED", "cat": "request", "ph": "X",
                      "pid": PID_REQUESTS, "tid": 3, "ts": 1e6, "dur": 1e6,
                      "args": {"prompt_len": 4}}
    assert any(e["name"] == "fault:stall" and e["pid"] == PID_ENGINE
               for e in evs)
    assert any(e["name"] == "finish" and e["args"]["reason"] == "stop"
               for e in evs)
    # disabled tracer records nothing
    off = Tracer(enabled=False)
    off.request_state(1, "QUEUED", 0.0)
    off.step_span(0.0, 1.0)
    assert off.events == []


def test_validate_trace_catches_malformed_events():
    assert validate_trace({"nope": 1})
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 0, "ts": -5, "dur": 1,
         "args": {}}]}
    probs = validate_trace(bad)
    assert any("bad ts" in p for p in probs)
    assert any("thread_name" in p for p in probs)


# ------------------------------------------- engine: accounting + determinism
def _mixed_workload(model, params, *, clock, tracer=None, metrics=True):
    """Prefill + decode + preemption + offload/restore + shed on one tiny
    paged engine, all in simulated time."""
    conf = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                        page_size=8, num_pages=6, eos_id=-1, clock=clock,
                        default_queue_timeout_s=4.0, preemption=True,
                        tracer=tracer, metrics=metrics)
    eng = Engine(model, params, conf)
    return eng


def _pump(eng, clk, prompts, max_steps=120):
    ra = eng.submit(prompts[0], max_new_tokens=10, sampling=GREEDY,
                    priority=0)
    outs = {}
    for _ in range(4):
        for o in eng.step():
            outs[o.rid] = o
        clk.advance(0.5)
    rb = eng.submit(prompts[1], max_new_tokens=10, sampling=GREEDY,
                    priority=1)                # preempts A (pool is tight)
    rc = eng.submit(prompts[2], max_new_tokens=4, sampling=GREEDY,
                    priority=0, queue_timeout_s=0.25)   # will be shed
    clk.advance(0.5)
    steps = 0
    while not eng.sched.idle and steps < max_steps:
        for o in eng.step():
            outs[o.rid] = o
        eng._events.clear()
        clk.advance(0.5)
        steps += 1
    assert eng.sched.idle
    return outs, (ra, rb, rc)


def test_metrics_agree_exactly_with_engine_stats(small_lm):
    """After a mixed prefill/decode/preemption/shed workload, every counter
    a /metrics scrape reports equals the EngineStats read-view exactly."""
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    eng = _mixed_workload(model, params, clock=clk)
    outs, (ra, rb, rc) = _pump(eng, clk, _prompts(cfg, [24, 24, 16], seed=21))
    s = eng.stats
    assert s.preemptions >= 1 and s.restored_pages > 0
    assert outs[rc].finish_reason is FinishReason.SHED

    parsed = M.parse_prometheus_text(eng.metrics.registry.expose())

    def scraped(family):
        return sum(v for n, _, v in parsed[family]["samples"] if n == family)

    for family, attr in [
            ("engine_tokens_generated_total", "tokens_generated"),
            ("engine_prefill_tokens_total", "prefill_tokens"),
            ("engine_steps_total", "steps"),
            ("engine_wall_seconds_total", "wall_s"),
            ("engine_prefix_hit_pages_total", "prefix_hit_pages"),
            ("engine_prefix_hit_tokens_total", "prefix_hit_tokens"),
            ("engine_preemptions_total", "preemptions"),
            ("engine_offloaded_pages_total", "offloaded_pages"),
            ("engine_offloaded_bytes_total", "offloaded_bytes"),
            ("engine_restored_pages_total", "restored_pages"),
            ("engine_shed_requests_total", "shed_requests"),
            ("engine_deferred_admissions_total", "deferred_admissions"),
            ("engine_peak_active", "peak_active")]:
        assert scraped(family) == getattr(s, attr), family

    # finished-by-reason counters sum to the requests that left the engine
    finished = {lab["reason"]: v
                for n, lab, v in parsed["engine_requests_finished_total"]
                ["samples"] if n == "engine_requests_finished_total"}
    assert finished.get("shed") == 1
    assert sum(finished.values()) == len(outs)
    # const labels stamp every sample
    _, lab, _ = parsed["engine_steps_total"]["samples"][0]
    assert lab["layout"] == "paged" and lab["kv_quant"] == "float32"
    # histograms saw the lifecycle: one ttft per served request
    served = [o for o in outs.values()
              if o.finish_reason is not FinishReason.SHED]
    assert scraped("engine_ttft_seconds") == 0     # no raw-name samples
    counts = [v for n, _, v in parsed["engine_ttft_seconds"]["samples"]
              if n == "engine_ttft_seconds_count"]
    assert sum(counts) == len(served)


def test_wall_s_is_clock_driven_in_every_pump(small_lm):
    """wall_s accumulates inside step() from the injectable clock — a stall
    that advances the ManualClock mid-step is charged to exactly that step,
    whether the engine is pumped via run(), generate(), or bare step()."""
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    inj = F.FaultInjector().stall_at(2, F.clock_stall(clk, 7.0))
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, cache="paged", page_size=8, eos_id=-1,
        clock=clk, faults=inj))
    eng.submit(_prompts(cfg, [12], seed=22)[0], max_new_tokens=6,
               sampling=GREEDY)
    for _ in range(3):                         # bare step() pump
        eng.step()
    assert eng.stats.wall_s == pytest.approx(7.0)   # only the stall advanced
    assert eng.metrics.step_duration.quantile(0.99) > 0
    assert eng.metrics.faults_injected.labels(kind="stall").value == 1


def test_trace_is_byte_deterministic_and_complete(small_lm):
    """Two identical ManualClock runs export byte-identical Perfetto JSON,
    and the trace carries the full lifecycle: QUEUED/PREFILL/RUNNING spans,
    PREEMPTED span with an offload instant, restore instant, step spans
    with page-pool occupancy, and one finish instant per request."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, [24, 24, 16], seed=21)
    blobs, tracers = [], []
    for _ in range(2):
        clk = ManualClock(0.0)
        tr = Tracer()
        eng = _mixed_workload(model, params, clock=clk, tracer=tr)
        _pump(eng, clk, prompts)
        assert eng.stats.preemptions >= 1
        tr.flush_open(clk.now())
        assert validate_trace(tr.to_dict()) == []
        blobs.append(tr.to_json())
        tracers.append(tr)
    assert blobs[0] == blobs[1], "ManualClock trace not byte-deterministic"

    evs = tracers[0].events
    names = [e["name"] for e in evs]
    for state in ("QUEUED", "PREFILL", "RUNNING", "PREEMPTED"):
        assert state in names, f"missing lifecycle span {state}"
    assert "offload" in names and "restore" in names
    finishes = [e for e in evs if e["name"] == "finish"]
    assert {e["args"]["reason"] for e in finishes} == {"length", "shed"}
    steps = [e for e in evs if e["name"] == "step"]
    assert steps and all("free_pages" in e["args"] for e in steps)
    assert all(e["pid"] == PID_ENGINE for e in steps)
    prefills = [e for e in evs if e["name"] == "prefill"]
    assert prefills and all("prefill_chunk" in e["args"] for e in prefills)


@pytest.mark.parametrize("layout,kvq", [("slot", None), ("paged", None),
                                        ("paged", "int8")],
                         ids=["slot-bf16", "paged-bf16", "paged-int8"])
def test_greedy_tokens_identical_with_observability_on_and_off(
        small_lm, layout, kvq):
    cfg, model, params = small_lm
    prompts = _prompts(cfg, [9, 14], seed=23)

    def run(metrics, tracer):
        eng = Engine(model, params, EngineConfig(
            batch_slots=2, max_len=64, cache=layout, page_size=8,
            eos_id=-1, kv_quant=kvq, metrics=metrics, tracer=tracer))
        return [o.output for o in eng.generate(prompts, max_new_tokens=8,
                                               sampling=GREEDY)]

    on = run(True, Tracer())
    off = run(False, None)
    assert on == off, "observability changed sampled tokens"


def test_decode_still_one_transfer_per_step_with_observability(
        small_lm, monkeypatch):
    """Metrics + tracing are host-side only: the decode loop still makes
    exactly one device->host transfer per step (the sampled tokens)."""
    import repro.serving.engine as engine_mod
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=32, eos_id=-1, cache="paged", page_size=8,
        tracer=Tracer()))
    for p in _prompts(cfg, [5, 7], seed=24):
        eng.submit(p, max_new_tokens=16, sampling=GREEDY)
    eng._admit([])                        # prefill outside the counted loop

    transfers = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        transfers["n"] += 1
        return real_get(x)

    monkeypatch.setattr(engine_mod.jax, "device_get", counting_get)
    steps = 3
    for _ in range(steps):
        eng.step()
    assert transfers["n"] == steps


def test_fault_injection_lands_in_counters_and_trace(small_lm):
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    tr = Tracer()
    inj = (F.FaultInjector().exhaust_pages_at(0, 999).release_pages_at(4)
           .stall_at(2, F.clock_stall(clk, 3.0)))
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, cache="paged", page_size=8, num_pages=6,
        eos_id=-1, clock=clk, faults=inj, tracer=tr, preemption=False))
    eng.submit(_prompts(cfg, [16], seed=25)[0], max_new_tokens=4,
               sampling=GREEDY)
    _drain(eng)
    fired = [k for _, k, _ in inj.log]
    assert fired == ["exhaust_pages", "stall", "release_pages"]
    # every fired fault: one counter increment, labeled by kind...
    fam = eng.metrics.faults_injected
    assert {k: fam.labels(kind=k).value for k in set(fired)} == {
        "exhaust_pages": 1.0, "stall": 1.0, "release_pages": 1.0}
    # ...and one instant on the engine trace track, in firing order
    instants = [e for e in tr.events if e["name"].startswith("fault:")]
    assert [e["name"] for e in instants] == [f"fault:{k}" for k in fired]
    assert all(e["pid"] == PID_ENGINE for e in instants)
    assert instants[0]["args"]["pages"] == 6
    assert validate_trace(tr.to_dict()) == []


# ---------------------------------------------------------------- HTTP layer
def _get(port, path, timeout=30):
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=timeout) as r:
            return r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


@pytest.fixture()
def http_server(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, cache="paged", page_size=8, eos_id=-1))
    srv = make_server(eng, model_name=cfg.name)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    yield cfg, srv, eng
    srv.shutdown()


def test_http_metrics_scrape_matches_engine(http_server):
    cfg, srv, eng = http_server
    prompt = _prompts(cfg, [10], seed=26)[0]
    body = json.dumps({"prompt": prompt, "max_tokens": 5,
                       "temperature": 0.0}).encode()
    req = urllib.request.Request(
        f"http://127.0.0.1:{srv.port}/v1/completions", data=body,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=120) as r:
        assert r.status == 200

    st, hdr, raw = _get(srv.port, "/metrics")
    assert st == 200
    assert hdr["Content-Type"].startswith("text/plain; version=0.0.4")
    parsed = M.parse_prometheus_text(raw.decode())
    toks = [v for n, _, v in parsed["engine_tokens_generated_total"]
            ["samples"] if n == "engine_tokens_generated_total"]
    # 5 output tokens = 1 sampled at prefill + 4 in the decode loop (the
    # counter's long-standing decode-only semantics)
    assert sum(toks) == eng.stats.tokens_generated == 4
    finished = [v for n, lab, v
                in parsed["engine_requests_finished_total"]["samples"]
                if lab.get("reason") == "length"]
    assert sum(finished) == 1


def test_http_healthz_reports_watchdog_state(small_lm):
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    eng = Engine(model, params, EngineConfig(
        batch_slots=1, max_len=32, eos_id=-1, clock=clk))
    srv = make_server(eng, stall_timeout_s=10.0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        st, _, raw = _get(srv.port, "/healthz")
        body = json.loads(raw)
        assert st == 200 and body["status"] == "ok"
        assert body["watchdog"] == "armed" and body["missed"] == 0

        clk.advance(25.0)                  # worker heartbeat goes stale
        st, _, raw = _get(srv.port, "/healthz")
        body = json.loads(raw)
        assert st == 503 and body["status"] == "stalled"
        assert body["heartbeat_stale_s"] >= 25.0
    finally:
        srv.shutdown()


def test_http_healthz_without_watchdog_is_disarmed(http_server):
    _cfg, srv, _eng = http_server
    st, _, raw = _get(srv.port, "/healthz")
    body = json.loads(raw)
    assert st == 200 and body == {"status": "ok", "watchdog": "disarmed"}


def test_http_unknown_paths_return_json_404(http_server):
    """Unknown routes get a clean JSON error envelope — for a plain blocking
    client and for an SSE-intending client alike (no hung stream, no HTML
    error page)."""
    cfg, srv, _eng = http_server
    # blocking GET client
    st, hdr, raw = _get(srv.port, "/v1/nope")
    assert st == 404 and hdr["Content-Type"] == "application/json"
    assert json.loads(raw) == {"error": {"message": "no route /v1/nope"}}
    # SSE-intending client: stream=true POSTed at a wrong path must get the
    # same JSON envelope immediately, not an event-stream that never opens
    body = json.dumps({"prompt": _prompts(cfg, [4], seed=27)[0],
                       "max_tokens": 2, "stream": True}).encode()
    req = urllib.request.Request(
        f"http://127.0.0.1:{srv.port}/v1/complete", data=body,
        headers={"Content-Type": "application/json",
                 "Accept": "text/event-stream"})
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            st, hdr, raw = r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        st, hdr, raw = e.code, dict(e.headers), e.read()
    assert st == 404 and hdr["Content-Type"] == "application/json"
    assert json.loads(raw)["error"]["message"] == "no route /v1/complete"
