import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gptq, packing


def _rand_w(k, n, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, size=(k, n)).astype(np.float32))


def _rand_h(k, nsamples=512, seed=1):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, size=(nsamples, k)).astype(np.float32)
    x[:, : k // 2] *= 4.0  # make some directions matter more
    return jnp.asarray(2.0 * x.T @ x)


def test_rtn_roundtrip_exact_grid():
    # weights already on the quant grid -> RTN is exact
    k, n, g = 64, 16, 32
    rng = np.random.default_rng(0)
    scales = rng.uniform(0.5, 2.0, size=(k // g, n)).astype(np.float32)
    zeros = rng.integers(0, 16, size=(k // g, n))
    q = rng.integers(0, 16, size=(k, n))
    # make every (group, column) span the full grid so min/max recovery is exact
    q.reshape(k // g, g, n)[:, 0, :] = 0
    q.reshape(k // g, g, n)[:, 1, :] = 15
    w = ((q.reshape(k // g, g, n) - zeros[:, None]) * scales[:, None]).reshape(k, n)
    ql = gptq.gptq_quantize(jnp.asarray(w, jnp.float32), None,
                            gptq.GPTQConfig(group_size=g))
    np.testing.assert_allclose(np.asarray(gptq.dequantize(ql)), w, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("group_size", [32, 64, -1])
@pytest.mark.parametrize("act_order", [False, True])
def test_gptq_beats_or_matches_reconstruction(group_size, act_order):
    k, n = 128, 64
    w = _rand_w(k, n)
    h = _rand_h(k)
    cfg = gptq.GPTQConfig(group_size=group_size, act_order=act_order)
    ql = gptq.gptq_quantize(w, h, cfg)
    err = float(gptq.quantization_error(w, ql, h))
    # hessian-weighted relative error must be small for 4 bits
    assert err < 0.05, err
    # and GPTQ should beat plain RTN on the hessian-weighted metric
    q_rtn, s_rtn, z_rtn = gptq.quantize_rtn(w, cfg)
    ql_rtn = gptq.QuantizedLinear(
        qweight=packing.pack_int4_rows(q_rtn), scales=s_rtn,
        qzeros=packing.pack_int4_cols(z_rtn.astype(jnp.int8)), perm=None,
        bias=None, shape=(k, n), group_size=group_size if group_size > 0 else k)
    err_rtn = float(gptq.quantization_error(w, ql_rtn, h))
    assert err <= err_rtn * 1.05, (err, err_rtn)


def test_act_order_permutation_consistency():
    k, n = 64, 32
    w = _rand_w(k, n, seed=3)
    h = _rand_h(k, seed=4)
    ql = gptq.gptq_quantize(w, h, gptq.GPTQConfig(group_size=32, act_order=True))
    assert ql.perm is not None
    # perm must be a permutation of arange(k)
    np.testing.assert_array_equal(np.sort(np.asarray(ql.perm)), np.arange(k))
    # dequantize returns original-order rows: matmul against x must approximate x@w
    x = _rand_w(8, k, seed=5).T[:8, :] if False else _rand_w(8, k, seed=5)
    y_ref = x @ w
    y_q = x @ gptq.dequantize(ql)
    rel = float(jnp.linalg.norm(y_q - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.1, rel


def test_hessian_accumulation_shape_and_psd():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, 32)), jnp.float32)
    h = gptq.accumulate_hessian(None, x)
    h = gptq.accumulate_hessian(h, x)
    assert h.shape == (32, 32)
    eig = np.linalg.eigvalsh(np.asarray(h))
    assert eig.min() >= -1e-3


def test_quantized_linear_is_pytree():
    w = _rand_w(32, 16)
    ql = gptq.gptq_quantize(w, None, gptq.GPTQConfig(group_size=16))
    leaves = jax.tree_util.tree_leaves(ql)
    assert len(leaves) == 3  # qweight, scales, qzeros (perm/bias None)
    ql2 = jax.tree_util.tree_map(lambda a: a, ql)
    assert ql2.shape == ql.shape


def test_dead_columns_handled():
    k, n = 32, 16
    w = _rand_w(k, n)
    h = np.array(_rand_h(k))  # writable copy
    h[0, :] = 0; h[:, 0] = 0  # dead input feature
    ql = gptq.gptq_quantize(w, jnp.asarray(h), gptq.GPTQConfig(group_size=16))
    assert np.isfinite(np.asarray(gptq.dequantize(ql))).all()
