import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import packing


@given(st.integers(1, 6), st.integers(1, 40), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_rows_roundtrip(kw, n, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 16, size=(kw * 8, n), dtype=np.int32)
    packed = packing.pack_int4_rows(jnp.asarray(w))
    assert packed.shape == (kw, n) and packed.dtype == jnp.int32
    out = packing.unpack_int4_rows(packed)
    np.testing.assert_array_equal(np.asarray(out), w.astype(np.int8))


@given(st.integers(1, 8), st.integers(1, 5), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_cols_roundtrip(g, nw, seed):
    rng = np.random.default_rng(seed)
    z = rng.integers(0, 16, size=(g, nw * 8), dtype=np.int32)
    packed = packing.pack_int4_cols(jnp.asarray(z))
    assert packed.shape == (g, nw)
    out = packing.unpack_int4_cols(packed)
    np.testing.assert_array_equal(np.asarray(out), z.astype(np.int8))


def test_numpy_twins_match_jnp():
    rng = np.random.default_rng(0)
    w = rng.integers(0, 16, size=(64, 24), dtype=np.int32)
    np.testing.assert_array_equal(
        packing.np_pack_int4_rows(w), np.asarray(packing.pack_int4_rows(jnp.asarray(w))))
    packed = packing.np_pack_int4_rows(w)
    np.testing.assert_array_equal(
        packing.np_unpack_int4_rows(packed), np.asarray(packing.unpack_int4_rows(jnp.asarray(packed))))


def test_nibble_order_lsb_first():
    # row 0 in least significant nibble (AutoGPTQ convention)
    w = jnp.asarray(np.arange(8, dtype=np.int32)[:, None])  # values 0..7 in col 0
    packed = packing.pack_int4_rows(w)
    assert int(packed[0, 0]) == 0x76543210
