"""Lint gates.

* Dead imports (ISSUE 2 satellite): ``pyflakes`` over ``src/`` when
  installed (``pip install -r requirements-dev.txt``), else a minimal
  AST-based unused-import check (imports bound at module level that are
  never referenced as a load anywhere in the module) so the gate still
  bites in dependency-free environments.  ``# noqa`` lines are exempt.
* Deprecated Engine kwargs (ISSUE 3 satellite): in-repo code under
  ``src/``, ``examples/`` and ``benchmarks/`` must construct the engine via
  ``Engine(model, params, EngineConfig(...))`` — the legacy 10-kwarg shim
  exists only for out-of-repo callers (and the tests that cover it).
* Injectable clocks in serving (ISSUE 6 satellite): no serving module may
  call ``time.time``/``time.monotonic`` directly — every deadline and
  timestamp must read through the engine's injectable clock
  (``serving/clock.py``), or overload tests cannot control time.
"""
from __future__ import annotations

import ast
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(_ROOT, "src")


def _have_pyflakes() -> bool:
    try:
        import pyflakes  # noqa: F401
        return True
    except ImportError:
        return False


def _unused_imports(path: str) -> list[str]:
    with open(path) as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    noqa_lines = {i + 1 for i, line in enumerate(source.splitlines())
                  if "# noqa" in line}
    imported: dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                imported[name] = node.lineno
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                imported[a.asname or a.name] = node.lineno
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            n = node
            while isinstance(n, ast.Attribute):
                n = n.value
            if isinstance(n, ast.Name):
                used.add(n.id)
    exported = set()
    for node in tree.body:     # __all__ re-exports
        if (isinstance(node, ast.Assign) and node.targets
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "__all__"):
            exported |= {getattr(e, "value", None)
                         for e in getattr(node.value, "elts", [])}
    return [f"{path}:{line}: unused import {name!r}"
            for name, line in sorted(imported.items(), key=lambda kv: kv[1])
            if name not in used and name not in exported
            and line not in noqa_lines]


# Engine.__init__'s legacy kwarg names — the deprecated shim.  New in-repo
# code passes these through EngineConfig instead.
DEPRECATED_ENGINE_KWARGS = frozenset({
    "batch_slots", "max_len", "kernels", "eos_id", "cache_dtype", "seed",
    "cache", "page_size", "num_pages"})


def _legacy_engine_calls(path: str) -> list[str]:
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        if name != "Engine":
            continue
        legacy = sorted({kw.arg for kw in node.keywords}
                        & DEPRECATED_ENGINE_KWARGS)
        if legacy:
            hits.append(f"{path}:{node.lineno}: Engine(...{legacy}...) uses "
                        f"the deprecated kwarg shim; pass EngineConfig")
    return hits


def test_no_in_repo_legacy_engine_kwargs():
    """src/, examples/ and benchmarks/ must use EngineConfig; the deprecated
    Engine(**old_kwargs) shim is for out-of-repo callers (its behaviour is
    covered by tests, which are exempt here)."""
    problems: list[str] = []
    for top in ("src", "examples", "benchmarks"):
        for dirpath, _dirs, files in os.walk(os.path.join(_ROOT, top)):
            for fn in files:
                if fn.endswith(".py"):
                    problems += _legacy_engine_calls(os.path.join(dirpath, fn))
    assert not problems, "\n".join(problems)


_BANNED_TIME_CALLS = frozenset({"time", "monotonic", "monotonic_ns",
                                "time_ns", "perf_counter"})


def _direct_time_calls(path: str) -> list[str]:
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _BANNED_TIME_CALLS
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "time"):
            hits.append(
                f"{path}:{node.lineno}: direct time.{fn.attr}() in a serving "
                f"module — read the engine's injectable clock "
                f"(serving/clock.py) instead")
    return hits


def test_serving_uses_injectable_clock():
    """Serving deadline/timestamp logic must be testable without sleeping:
    ``serving/clock.py::SystemClock`` is the single permitted ``time.time``
    call site; everything else in ``src/repro/serving/`` reads
    ``engine.clock.now()`` (DESIGN.md §14).  The observability layer is
    explicitly in scope (ISSUE 7): ``metrics.py`` observes values the
    engine timestamps and ``tracing.py`` never reads a clock at all —
    that's what makes ManualClock traces byte-deterministic."""
    serving = os.path.join(SRC, "repro", "serving")
    problems: list[str] = []
    walked: set[str] = set()
    for dirpath, _dirs, files in os.walk(serving):
        for fn in files:
            if fn.endswith(".py") and fn != "clock.py":
                walked.add(fn)
                problems += _direct_time_calls(os.path.join(dirpath, fn))
    assert not problems, "\n".join(problems)
    assert {"metrics.py", "tracing.py", "engine.py", "http_api.py",
            "spec_decode.py"} <= walked, (
        f"observability modules fell out of the clock gate: {sorted(walked)}")


def test_src_has_no_dead_imports():
    if _have_pyflakes():
        proc = subprocess.run(
            [sys.executable, "-m", "pyflakes", SRC],
            capture_output=True, text=True)
        offending = [l for l in proc.stdout.splitlines()
                     if "imported but unused" in l]
        assert not offending, "\n".join(offending)
        return
    problems: list[str] = []
    for dirpath, _dirs, files in os.walk(SRC):
        for fn in files:
            if fn.endswith(".py") and fn != "__init__.py":
                problems += _unused_imports(os.path.join(dirpath, fn))
    assert not problems, "\n".join(problems)
