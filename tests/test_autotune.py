"""Autotuner tests: candidate legality, cost-model pruning, cache round-trip
(same key -> cached config with no re-timing), and end-to-end "auto" blocks
through the ops dispatcher."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gptq
from repro.core.opt_strategies import OPT4GPTQ, get_strategy
from repro.kernels import autotune, ops


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "autotune.json"))
    autotune.clear_memory_cache()
    yield
    autotune.clear_memory_cache()


def test_candidates_are_legal():
    for m, k, n, g in [(1, 256, 128, 64), (8, 1024, 1024, 128),
                       (128, 512, 256, -1), (4, 128, 60 + 4, 64)]:
        cands = autotune.candidate_blocks(m, k, n, g)
        assert cands
        gg = g if g > 0 else k
        for bm, bn, bk in cands:
            assert bm % 8 == 0 and bn % 8 == 0 and bk % 8 == 0
            assert k % bk == 0
            assert bk % gg == 0 or gg % bk == 0


def test_prune_keeps_near_optimal_front():
    m, k, n, g = 8, 1024, 1024, 128
    cands = autotune.candidate_blocks(m, k, n, g)
    kept = autotune.prune_candidates(cands, m, k, n, g, OPT4GPTQ)
    assert 1 <= len(kept) <= autotune.MAX_TIMED
    assert set(kept) <= set(cands)
    from repro.core.perf_model import gptq_matmul_cost

    def modeled(c):
        return gptq_matmul_cost(m, k, n, group_size=g, strategy=OPT4GPTQ,
                                bk=c[2]).time_s

    best = min(modeled(c) for c in cands)
    # every survivor is within the prune factor of the modeled optimum
    assert all(modeled(c) <= best * autotune.PRUNE_FACTOR for c in kept)
    # and the front prefers larger tiles on model ties (fewer launches)
    assert kept[0][1] * kept[0][2] == max(bn * bk for _, bn, bk in kept)


def test_cache_roundtrip_no_retiming():
    m, k, n, g = 4, 256, 128, 64
    cfg = autotune.get_block_sizes(m, k, n, g, OPT4GPTQ)
    assert len(cfg) == 3
    timed = len(autotune.timed_keys)
    # memory hit
    assert autotune.get_block_sizes(m, k, n, g, OPT4GPTQ) == cfg
    assert len(autotune.timed_keys) == timed
    # file hit (fresh process simulation)
    autotune.clear_memory_cache()
    assert autotune.get_block_sizes(m, k, n, g, OPT4GPTQ) == cfg
    assert len(autotune.timed_keys) == timed
    data = json.load(open(autotune.cache_path()))
    assert data[autotune.cache_key(m, k, n, g, OPT4GPTQ)] == list(cfg)


def test_distinct_keys_per_strategy_lane_and_mode():
    k1 = autotune.cache_key(4, 256, 128, 64, OPT4GPTQ)
    k2 = autotune.cache_key(4, 256, 128, 64, get_strategy("baseline"))
    k3 = autotune.cache_key(64, 256, 128, 64, OPT4GPTQ)
    k4 = autotune.cache_key(4, 256, 128, 64, OPT4GPTQ, interpret=False)
    assert len({k1, k2, k3, k4}) == 4
    assert ":gemv:" in k1 and ":matmul:" in k3
    # interpreter-mode timings must never be reused for compiled runs
    assert k1.endswith("interp") and k4.endswith("compiled")


def test_auto_blocks_through_ops_match_oracle():
    rng = np.random.default_rng(0)
    k, n, g = 256, 128, 64
    w = jnp.asarray(rng.normal(0, 0.5, (k, n)).astype(np.float32))
    ql = gptq.gptq_quantize(w, None, gptq.GPTQConfig(group_size=g))
    for m in (3, 16):
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        y_ref = ops.gptq_linear(ql, x, use_pallas=False)
        y = ops.gptq_linear(ql, x, use_pallas=True, block_sizes="auto")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-2, atol=2e-2)
