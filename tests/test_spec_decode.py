"""Speculative decoding tests (ISSUE 8 / DESIGN.md §16): n-gram prompt-lookup
and draft-model proposers, the batched verify/accept core, greedy
token-identity with plain decode across cache layouts and kv-quant modes
(property-tested), rollback losslessness under int8 per-page scales at the
PagedCache data path, mid-stream preemption of a speculating request, and
the spec counters surfaced through EngineStats / RequestOutput / metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import metrics as M
from repro.serving.api import EngineConfig, FinishReason
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedCache
from repro.serving.kv_quant import KVQuantConfig
from repro.serving.sampler import SamplingParams, accept_speculative
from repro.serving.spec_decode import (MAX_SPEC_K, NGramSpeculator,
                                       SpecConfig, ngram_propose)

GREEDY = SamplingParams(greedy=True)


_LM: list = []


def _lm():
    """Module-memoized smoke model — shared by the fixture-based tests and
    the ``@given`` property tests (the hypothesis shim hides the wrapped
    signature from pytest, so those can't take fixtures)."""
    if not _LM:
        cfg = smoke_config("qwen3_4b")
        model = build_model(cfg)
        _LM.append((cfg, model, model.init(jax.random.key(0))))
    return _LM[0]


@pytest.fixture(scope="module")
def small_lm():
    return _lm()


def _prompts(cfg, seed=0):
    """One repetitive prompt (n-gram bait) and one random prompt."""
    rng = np.random.default_rng(seed)
    pat = rng.integers(2, cfg.vocab_size, size=4).tolist()
    return [pat * 3 + pat[:2],
            rng.integers(2, cfg.vocab_size, size=9).tolist()]


# ------------------------------------------------------------- ngram proposer
def test_ngram_propose_longest_suffix_match():
    ctx = [1, 2, 3, 4, 1, 2, 3, 4, 1, 2]
    # suffix 4-gram [3, 4, 1, 2] recurs at index 2 -> continuation [3, 4, 1]
    assert ngram_propose(ctx, k=3, ngram_max=4, ngram_min=1) == [3, 4, 1]
    # a request past the context end extrapolates the period
    assert ngram_propose(ctx, k=8, ngram_max=4, ngram_min=1) \
        == [3, 4, 1, 2, 3, 4, 1, 2]


def test_ngram_propose_extrapolates_constant_run():
    ctx = [9, 9, 9, 9, 9, 9]
    assert ngram_propose(ctx, k=4, ngram_max=4, ngram_min=1) == [9, 9, 9, 9]


def test_ngram_propose_prefers_most_recent_occurrence():
    ctx = [7, 1, 2, 9, 1, 2, 8, 1, 2]
    assert ngram_propose(ctx, k=1, ngram_max=4, ngram_min=1) == [8]


def test_ngram_propose_no_match_and_degenerate():
    assert ngram_propose([1, 2, 3, 4, 5], k=4, ngram_max=4, ngram_min=1) == []
    assert ngram_propose([1, 2, 1, 2], k=0, ngram_max=4, ngram_min=1) == []
    assert ngram_propose([5], k=4, ngram_max=4, ngram_min=1) == []


def test_ngram_speculator_respects_caps():
    spec = NGramSpeculator(SpecConfig(method="ngram", k=4), batch_rows=3)
    ctx = [1, 2, 3, 1, 2, 3, 1, 2]
    prop = spec.propose({0: (10, ctx, 4), 1: (11, ctx, 1), 2: (12, ctx, 0)},
                        all_greedy=True)
    assert prop.draft_lens.tolist() == [4, 1, 0]
    assert prop.drafts[0].tolist() == [3, 1, 2, 3]
    assert prop.drafts[1, 0] == 3


# ------------------------------------------------------------ accept (greedy)
def _onehot_logits(targets, v=16):
    """(B, S) target ids -> (B, S, V) logits whose argmax is ``targets``."""
    t = np.asarray(targets)
    out = np.full(t.shape + (v,), -5.0, np.float32)
    np.put_along_axis(out, t[..., None], 5.0, axis=-1)
    return jnp.asarray(out)


def test_accept_speculative_greedy_prefix():
    # row 0: drafts match targets at positions 0,1, mismatch at 2
    # row 1: zero drafts proposed -> plain decode step (bonus only)
    logits = _onehot_logits([[3, 4, 9, 6], [7, 1, 1, 1]])
    drafts = jnp.asarray([[3, 4, 5], [2, 2, 2]], jnp.int32)
    lens = jnp.asarray([3, 0], jnp.int32)
    n_acc, emitted = accept_speculative(logits, drafts, lens, all_greedy=True)
    assert n_acc.tolist() == [2, 0]
    assert emitted[0, :4].tolist() == [3, 4, 9, 0]   # d0 d1 bonus, zero tail
    assert emitted[1, :2].tolist() == [7, 0]


def test_accept_speculative_greedy_full_accept_takes_bonus():
    logits = _onehot_logits([[3, 4, 5, 6]])
    drafts = jnp.asarray([[3, 4, 5]], jnp.int32)
    lens = jnp.asarray([3], jnp.int32)
    n_acc, emitted = accept_speculative(logits, drafts, lens, all_greedy=True)
    assert n_acc.tolist() == [3]
    assert emitted[0].tolist() == [3, 4, 5, 6]       # all drafts + bonus


def test_accept_speculative_draft_lens_mask():
    """Positions past draft_lens never count as accepted even if they would
    match the target argmax."""
    logits = _onehot_logits([[3, 4, 5, 6]])
    drafts = jnp.asarray([[3, 4, 5]], jnp.int32)
    lens = jnp.asarray([1], jnp.int32)
    n_acc, emitted = accept_speculative(logits, drafts, lens, all_greedy=True)
    assert n_acc.tolist() == [1]
    assert emitted[0, :3].tolist() == [3, 4, 0]


# --------------------------------------------- engine: greedy token identity
_ENGINES: dict = {}


def _engine_pair(model, params, layout, k, kvq=None):
    """Plain + speculating engine pair, cached across property examples so
    each (layout, k) compiles once."""
    key = (layout, k, kvq)
    if key not in _ENGINES:
        base = dict(batch_slots=2, max_len=64, eos_id=-1, cache=layout,
                    kv_quant=kvq)
        _ENGINES[key] = (
            Engine(model, params, EngineConfig(**base)),
            Engine(model, params, EngineConfig(
                **base, speculation=SpecConfig(method="ngram", k=k))))
    return _ENGINES[key]


def _check_greedy_identity(layout, seed, k):
    cfg, model, params = _lm()
    plain, spec = _engine_pair(model, params, layout, k)
    prompts = _prompts(cfg, seed=seed)
    ref = plain.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                         ignore_eos=True)
    out = spec.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                        ignore_eos=True)
    for r, o in zip(ref, out):
        assert r.output == o.output, (layout, seed, k)
        assert len(o.output) == 8 and o.finish_reason is FinishReason.LENGTH


@settings(max_examples=4)
@given(st.integers(min_value=0, max_value=7),
       st.integers(min_value=1, max_value=4))
def test_spec_greedy_identical_to_plain_slot(seed, k):
    """The tentpole invariant: greedy speculative decode is token-for-token
    identical to plain decode — any seed, any draft length."""
    _check_greedy_identity("slot", seed, k)


@settings(max_examples=4)
@given(st.integers(min_value=0, max_value=7),
       st.integers(min_value=1, max_value=4))
def test_spec_greedy_identical_to_plain_paged(seed, k):
    _check_greedy_identity("paged", seed, k)


@pytest.mark.parametrize("layout", ["slot", "paged"])
@pytest.mark.parametrize("kvq", ["bf16", "int8"])
def test_spec_greedy_identical_under_kv_quant(small_lm, layout, kvq):
    cfg, model, params = small_lm
    plain, spec = _engine_pair(model, params, layout, 3, kvq=kvq)
    prompts = _prompts(cfg, seed=1)
    ref = plain.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                         ignore_eos=True)
    out = spec.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                        ignore_eos=True)
    for r, o in zip(ref, out):
        assert r.output == o.output, (layout, kvq)


def test_spec_never_exceeds_max_new(small_lm):
    """A full acceptance plus bonus on the last verify span must land
    exactly on max_new_tokens, never past it (per-row draft caps)."""
    cfg, model, params = small_lm
    _, spec = _engine_pair(model, params, "paged", 4)
    prompts = _prompts(cfg, seed=2)
    for mn in (1, 2, 5):
        outs = spec.generate(prompts, max_new_tokens=mn, sampling=GREEDY,
                             ignore_eos=True)
        assert all(len(o.output) == mn for o in outs)


# ----------------------------------------------------- engine: draft proposer
def test_draft_speculator_self_draft_full_acceptance(small_lm):
    """Draft == target: every draft accepts, so each verify step commits
    k + 1 tokens and the engine takes ~1/(k+1) the steps of plain decode."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, seed=3)
    plain = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, eos_id=-1, cache="paged"))
    ref = plain.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                         ignore_eos=True)
    spec = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, eos_id=-1, cache="paged",
        speculation=SpecConfig(method="draft", k=3, draft_model=model,
                               draft_params=params)))
    out = spec.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                        ignore_eos=True)
    for r, o in zip(ref, out):
        assert r.output == o.output
    assert spec.stats.acceptance_rate == 1.0
    assert spec.stats.steps < plain.stats.steps
    assert spec.stats.tokens_per_step > plain.stats.tokens_per_step


def test_draft_speculator_bad_draft_still_identical(small_lm):
    """Correctness must not depend on draft quality: a randomly-initialized
    draft model (low acceptance) still yields the plain greedy tokens."""
    cfg, model, params = small_lm
    dmodel = build_model(cfg)
    dparams = dmodel.init(jax.random.key(99))
    prompts = _prompts(cfg, seed=4)
    plain = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, eos_id=-1, cache="paged"))
    ref = plain.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                         ignore_eos=True)
    spec = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, eos_id=-1, cache="paged",
        speculation=SpecConfig(method="draft", k=3, draft_model=dmodel,
                               draft_params=dparams)))
    out = spec.generate(prompts, max_new_tokens=8, sampling=GREEDY,
                        ignore_eos=True)
    for r, o in zip(ref, out):
        assert r.output == o.output
    assert spec.stats.spec_proposed > 0


def test_draft_vocab_mismatch_raises(small_lm):
    import dataclasses as dc
    cfg, model, params = small_lm
    other = dc.replace(smoke_config("qwen3_4b"),
                       vocab_size=cfg.vocab_size * 2)
    dmodel = build_model(other)
    dparams = dmodel.init(jax.random.key(1))
    with pytest.raises(ValueError, match="vocab"):
        Engine(model, params, EngineConfig(
            batch_slots=2, max_len=64, eos_id=-1,
            speculation=SpecConfig(method="draft", k=2, draft_model=dmodel,
                                   draft_params=dparams)))


# ------------------------------------------- engine: preemption mid-stream
def test_preemption_of_speculating_request_is_lossless(small_lm):
    """A speculating victim preempted mid-stream (pages offloaded) restores
    and finishes with greedy output identical to an unconstrained plain
    run — speculator state is invalidated and rebuilt transparently."""
    cfg, model, params = small_lm
    rng = np.random.default_rng(5)
    pat = rng.integers(2, cfg.vocab_size, size=4).tolist()
    pA = pat * 5 + pat[:2]                       # long + repetitive
    pB = rng.integers(2, cfg.vocab_size, size=24).tolist()

    roomy = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                         page_size=8, eos_id=-1)
    ref = Engine(model, params, roomy).generate(
        [pA, pB], max_new_tokens=12, sampling=GREEDY, ignore_eos=True)
    ref = {o.rid: o.output for o in ref}

    tight = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                         page_size=8, num_pages=6, eos_id=-1,
                         preemption=True,
                         speculation=SpecConfig(method="ngram", k=3))
    eng = Engine(model, params, tight)
    ra = eng.submit(pA, max_new_tokens=12, sampling=GREEDY, priority=0,
                    ignore_eos=True)
    for _ in range(4):                           # A speculates a few steps
        eng.step()
    rb = eng.submit(pB, max_new_tokens=12, sampling=GREEDY, priority=1,
                    ignore_eos=True)
    outs = {}
    steps = 0
    while not eng.sched.idle and steps < 300:
        for o in eng.step():
            outs[o.rid] = o
        eng._events.clear()
        steps += 1
    assert eng.sched.idle
    assert eng.stats.preemptions >= 1
    assert outs[ra].output == ref[0], "victim's tokens changed"
    assert outs[rb].output == ref[1], "preemptor's tokens changed"


# --------------------------------------------------- engine: sampled batches
def test_spec_sampled_batches_run(small_lm):
    """Non-greedy speculation: correct lengths, sane counters, and mixed
    greedy/sampled batches share one verify trace."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg, seed=6)
    sp = SamplingParams(temperature=0.8, top_k=50, top_p=0.95)
    for method, kw in (("ngram", {}),
                       ("draft", dict(draft_model=model,
                                      draft_params=params))):
        eng = Engine(model, params, EngineConfig(
            batch_slots=2, max_len=64, eos_id=-1, cache="paged",
            speculation=SpecConfig(method=method, k=3, **kw)))
        outs = eng.generate(prompts, max_new_tokens=8, sampling=sp,
                            ignore_eos=True)
        assert all(len(o.output) == 8 for o in outs)
        assert eng.stats.spec_accepted <= eng.stats.spec_proposed
        mixed = eng.generate(prompts, max_new_tokens=6,
                             sampling=[GREEDY, sp], ignore_eos=True)
        assert all(len(o.output) == 6 for o in mixed)


def test_draft_rejection_sampling_exact_on_self_draft(small_lm):
    """With q == p the rejection test ``u * q(d) <= p(d)`` accepts every
    draft: sampled self-draft speculation must show acceptance rate 1."""
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, eos_id=-1, cache="paged",
        speculation=SpecConfig(method="draft", k=3, draft_model=model,
                               draft_params=params)))
    outs = eng.generate(_prompts(cfg, seed=7), max_new_tokens=8,
                        sampling=SamplingParams(temperature=0.7),
                        ignore_eos=True)
    assert all(len(o.output) == 8 for o in outs)
    assert eng.stats.acceptance_rate == 1.0


# ------------------------------------------------ counters / config plumbing
def test_spec_counters_and_metrics_surface(small_lm):
    cfg, model, params = small_lm
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, eos_id=-1, cache="paged",
        speculation=SpecConfig(method="draft", k=3, draft_model=model,
                               draft_params=params)))
    outs = eng.generate(_prompts(cfg, seed=8), max_new_tokens=8,
                        sampling=GREEDY, ignore_eos=True)
    s = eng.stats
    assert s.spec_proposed > 0 and s.spec_accepted > 0
    assert s.spec_verify_steps > 0
    assert s.tokens_per_step > 1.0
    assert "spec_proposed" in repr(s) and "spec_accepted" in repr(s)
    # per-request accounting survives into RequestOutput
    for o in outs:
        assert o.spec_proposed >= o.spec_accepted > 0
        assert 0.0 < o.acceptance_rate <= 1.0
    assert sum(o.spec_proposed for o in outs) == s.spec_proposed
    assert sum(o.spec_accepted for o in outs) == s.spec_accepted
    # Prometheus exposition carries the counters and the accept histogram
    parsed = M.parse_prometheus_text(eng.metrics.registry.expose())
    for fam, attr in (("engine_spec_proposed_total", "spec_proposed"),
                      ("engine_spec_accepted_total", "spec_accepted"),
                      ("engine_spec_verify_steps_total",
                       "spec_verify_steps")):
        (_, _, value), = parsed[fam]["samples"]
        assert value == getattr(s, attr)
    assert parsed["engine_spec_accept_length"]["type"] == "histogram"


def test_spec_config_validation():
    with pytest.raises(ValueError, match="method"):
        SpecConfig(method="oracle")
    with pytest.raises(ValueError, match="k must be"):
        SpecConfig(k=0)
    with pytest.raises(ValueError, match="k must be"):
        SpecConfig(k=MAX_SPEC_K + 1)
    with pytest.raises(ValueError, match="ngram_min"):
        SpecConfig(ngram_min=3, ngram_max=2)
    with pytest.raises(ValueError, match="draft"):
        SpecConfig(method="draft")


def test_engine_config_speculation_validation():
    with pytest.raises(ValueError, match="SpecConfig"):
        EngineConfig(batch_slots=2, max_len=64, speculation="ngram")
    with pytest.raises(ValueError, match="max_len"):
        EngineConfig(batch_slots=2, max_len=8,
                     speculation=SpecConfig(method="ngram", k=8))


# ------------------------------------- PagedCache: int8-per-page rollback
def _rand_kv(rng, n_layers, n, heads, dim):
    k = jnp.asarray(rng.normal(size=(n_layers, n, heads, dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_layers, n, heads, dim)), jnp.float32)
    return k, v


def _seq_bytes(pc, seq_id):
    """Raw payload (+scale) bytes of a sequence's pages, valid extent only
    implicitly included — page granularity stores whole-page state."""
    idx = np.asarray(pc.tables[seq_id], np.int32)
    out = [np.asarray(pc.k_pages[:, idx]), np.asarray(pc.v_pages[:, idx])]
    if pc.k_scales is not None:
        out += [np.asarray(pc.k_scales[:, idx]),
                np.asarray(pc.v_scales[:, idx])]
    return out


@pytest.mark.parametrize("kvq", [
    None,
    KVQuantConfig(dtype="int8", granularity="token"),
    KVQuantConfig(dtype="int8", granularity="page"),
], ids=["fp32", "int8-token", "int8-page"])
def test_spec_rollback_roundtrips_losslessly(kvq):
    """The rollback contract (DESIGN.md §16): snapshot -> speculative write
    of k tokens -> truncate -> re-append the accepted prefix must produce
    bytes identical to having only ever written the accepted prefix.  This
    is the int8-per-*page* coverage — appends requantize whole pages, so
    only the snapshot's tail-payload restore makes the round trip exact
    (the engine itself runs per-token scales; per-page is data-path-only)."""
    rng = np.random.default_rng(11)
    mk = lambda: PagedCache(num_pages=6, page_size=8, n_layers=2,
                            kv_heads=2, head_dim=4, kv_quant=kvq)
    a, b = mk(), mk()
    assert a._hash_seed == b._hash_seed

    base_k, base_v = _rand_kv(rng, 2, 5, 2, 4)       # 5-token prompt
    spec_k, spec_v = _rand_kv(rng, 2, 4, 2, 4)       # 4 speculative tokens
    n_accept = 2

    for pc in (a, b):
        assert pc.alloc_seq(0, 5)
        pc.write_prefill(0, 0, base_k, base_v)

    # cache A speculates 4 tokens then rolls back to 2 accepted
    snap = a.spec_snapshot(0)
    assert a.extend_seq(0, 4)
    a.write_prefill(0, 5, spec_k, spec_v)
    a.truncate_seq(0, snap)
    assert a.lengths[0] == 5
    assert a.extend_seq(0, n_accept)
    a.write_prefill(0, 5, spec_k[:, :n_accept], spec_v[:, :n_accept])

    # cache B only ever writes the accepted prefix
    assert b.extend_seq(0, n_accept)
    b.write_prefill(0, 5, spec_k[:, :n_accept], spec_v[:, :n_accept])

    for got, want in zip(_seq_bytes(a, 0), _seq_bytes(b, 0)):
        np.testing.assert_array_equal(got, want)
    for layer in range(2):
        ka, va = a.gather_kv(0, layer)
        kb, vb = b.gather_kv(0, layer)
        np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    # rollback freed the page the speculative span had grown into
    assert len(a.tables[0]) == len(b.tables[0])
    assert sorted(a.free_list) == sorted(b.free_list)


def test_truncate_seq_refuses_shorter_than_snapshot():
    pc = PagedCache(num_pages=4, page_size=8, n_layers=1, kv_heads=1,
                    head_dim=4)
    assert pc.alloc_seq(0, 5)
    snap = pc.spec_snapshot(0)
    pc.lengths[0] = 3
    with pytest.raises(ValueError, match="shorter"):
        pc.truncate_seq(0, snap)
