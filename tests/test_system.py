"""End-to-end behaviour tests: the full pipeline the paper describes —
train -> calibrate -> GPTQ-quantize -> serve with the optimized kernels —
plus cross-cutting invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import OPT4GPTQ
from repro.core.quantize_model import dequantize_tree, quantize_params
from repro.data.pipeline import LMDataPipeline
from repro.models import build_model
from repro.models import layers as L
from repro.serving.engine import Engine
from repro.training import optimizer as O
from repro.training.train_loop import init_train_state, make_train_step

# full train->quantize->serve pipelines: slow tier (run via --runslow)
pytestmark = pytest.mark.slow


def test_full_pipeline_train_quantize_serve():
    """The paper's deployment story end to end on a reduced model."""
    cfg = dataclasses.replace(smoke_config("qwen3_4b"), scan_layers=False)
    model = build_model(cfg)
    opt = O.OptimizerConfig(learning_rate=2e-3, warmup_steps=2, total_steps=30)
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt))
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=24, global_batch=4)
    first = last = None
    for s in range(30):
        state, m = step(state, {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()})
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first

    # calibrate + quantize
    with L.capture_hessians() as ctx:
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        model.apply(state.params, b, mode="train")
    assert len(ctx.hessians) >= cfg.num_layers * 4   # per-layer projections seen
    qparams = quantize_params(state.params, dict(ctx.hessians),
                              GPTQConfig(group_size=32))

    # quantized model stays close to fp in function space
    logits_fp, _, _ = model.apply(state.params, b, mode="train")
    logits_q, _, _ = model.apply(qparams, b, mode="train")
    agree = float((logits_q.argmax(-1) == logits_fp.argmax(-1)).mean())
    assert agree > 0.9, agree

    # serve it with the paper's full optimization strategy (Pallas kernels)
    kern = L.KernelConfig(strategy=OPT4GPTQ, use_pallas=True,
                          block_sizes=(8, 64, 64))
    eng = Engine(model, qparams, batch_slots=2, max_len=48, kernels=kern,
                 eos_id=-1)
    eng.submit([5, 6, 7, 8], max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 4


def test_dequantize_tree_roundtrip_shapes():
    cfg = smoke_config("grok1_314b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    q = quantize_params(params, None, GPTQConfig(group_size=32))
    dq = dequantize_tree(q, jnp.float32)
    for a, b in zip(jax.tree_util.tree_leaves(jax.eval_shape(lambda: params)),
                    jax.tree_util.tree_leaves(jax.eval_shape(lambda: dq))):
        assert a.shape == b.shape, (a.shape, b.shape)


def test_applicability_matrix_counts():
    """DESIGN.md §4: 31 runnable cells + 9 rule-skips per mesh."""
    runnable = skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = applicable(cfg, shape)
            runnable += ok
            skipped += not ok
            if not ok:
                assert why
    assert runnable == 31 and skipped == 9


def test_quantization_compression_ratio():
    """int4 + group-128 scales should compress projections ~7-8x vs fp32."""
    cfg = smoke_config("codeqwen1p5_7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))

    def proj_bytes(tree):
        tot = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(
                tree, is_leaf=lambda x: hasattr(x, "qweight")):
            if hasattr(leaf, "qweight"):
                for a in (leaf.qweight, leaf.scales, leaf.qzeros):
                    tot += a.size * a.dtype.itemsize
            elif "group" in str(path) and getattr(leaf, "ndim", 0) >= 2:
                tot += leaf.size * leaf.dtype.itemsize
        return tot

    q = quantize_params(params, None, GPTQConfig(group_size=32))
    ratio = proj_bytes(params) / proj_bytes(q)
    assert ratio > 4.5, ratio   # group=32 fp32 scales cost more; >=4.5x holds
