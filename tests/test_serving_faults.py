"""Overload-resilience tests (ISSUE 6 / DESIGN.md §14): priority preemption
with host-memory page offload (lossless round trip, bf16 and int8 KV),
PagedCache offload/restore bookkeeping (refcounts, shared prefixes, donor
eviction), bounded admission + deadline shedding (engine and HTTP: 429 with
Retry-After, 503), the engine-worker watchdog (no stream hangs on a stalled
engine), the serving fault-injection harness, monitor-side heartbeat
staleness, and quant-mode-seeded prefix-cache hashing."""
import json
import os
import threading
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.perf import memory_model as MM
from repro.runtime.fault_tolerance import Heartbeat
from repro.serving import faults as F
from repro.serving.api import (EngineConfig, FinishReason, QueueFullError,
                               RequestState)
from repro.serving.clock import ManualClock, SystemClock
from repro.serving.engine import Engine
from repro.serving.http_api import make_server
from repro.serving.kv_cache import PagedCache
from repro.serving.sampler import SamplingParams
from repro.serving.spec_decode import SpecConfig

GREEDY = SamplingParams(greedy=True)

# ISSUE 8 satellite: CI runs this suite a second time with REPRO_SPEC=1 so
# the overload machinery (preemption, shedding, watchdog, fault injection)
# is exercised composed with speculative decoding — greedy outputs are
# token-identical either way, so every assertion below holds unchanged.
_SPEC = (SpecConfig(method="ngram", k=2)
         if os.environ.get("REPRO_SPEC") else None)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, size=n).tolist() for n in sizes]


def _drain(eng, max_steps=300):
    outs = {}
    steps = 0
    while not eng.sched.idle and steps < max_steps:
        for o in eng.step():
            outs[o.rid] = o
        eng._events.clear()
        steps += 1
    assert eng.sched.idle, "engine did not drain"
    return outs


# ---------------------------------------------------------------- PagedCache
def _stamped_cache(n_layers=1, kv_heads=1, head_dim=2, page_size=4,
                   num_pages=16):
    pc = PagedCache(num_pages=num_pages, page_size=page_size,
                    n_layers=n_layers, kv_heads=kv_heads, head_dim=head_dim)
    # make every physical page's payload identifiable
    n = pc.k_pages.shape[1]
    pc.k_pages = jnp.arange(n, dtype=pc.dtype).reshape(1, n, 1, 1, 1) * (
        jnp.ones_like(pc.k_pages))
    pc.v_pages = pc.k_pages * 2 + 1
    return pc


def test_offload_restore_round_trip_bit_identical():
    pc = _stamped_cache()
    toks = list(range(10))
    assert pc.alloc_seq(0, 10, tokens=toks, reserve=2)
    tab = list(pc.tables[0])
    want_k = np.asarray(pc.k_pages)[:, tab[:3]]
    want_v = np.asarray(pc.v_pages)[:, tab[:3]]
    free_before = len(pc.free_list)

    rec = pc.offload(0)
    assert rec.shared_pages == 0 and rec.n_payload_pages == 3
    assert rec.nbytes > 0 and pc.offloaded_bytes == rec.nbytes
    # everything released: row, pages (incl. reserve), length
    assert 0 not in pc.tables and 0 not in pc.rows
    assert len(pc.free_list) == free_before + len(tab)
    # host checkpoint bytes match the analytic model (K + V pools)
    from repro.serving.kv_quant import page_bytes
    assert rec.nbytes == page_bytes(
        pc.n_layers, pc.kv_heads, pc.head_dim, pc.page_size,
        dtype=pc.compute_dtype) * rec.n_payload_pages

    # scribble the pool; restore must rewrite the snapshot exactly
    pc.k_pages = jnp.zeros_like(pc.k_pages)
    pc.v_pages = jnp.zeros_like(pc.v_pages)
    r = pc.restore(0, toks, reserve=2)
    assert r is not None and r.restored_pages == 3
    assert r.hit_pages == 0 and r.snap_start_page == 0
    tab2 = pc.tables[0]
    assert pc.lengths[0] == 10
    np.testing.assert_array_equal(np.asarray(pc.k_pages)[:, tab2[:3]], want_k)
    np.testing.assert_array_equal(np.asarray(pc.v_pages)[:, tab2[:3]], want_v)
    assert not pc.offloaded and pc.offloaded_bytes == 0


def test_offload_releases_shared_prefix_without_copy():
    pc = _stamped_cache()
    toks = list(range(8)) + [99, 98]          # 2 full prefix pages + tail
    assert pc.alloc_seq(0, 10, tokens=toks)
    pc.register_prefix(0, toks)
    assert pc.alloc_seq(1, 10, tokens=toks)
    assert pc.prefix_hits[1] == 2
    shared_pages = pc.tables[1][:2]

    rec = pc.offload(1)
    # only the private tail page was copied; prefix pages just deref'd
    assert rec.shared_pages == 2 and rec.n_payload_pages == 1
    assert all(pc.refcount[p] == 1 for p in shared_pages)

    r = pc.restore(1, toks)
    # donor still live -> prefix re-shared through the hash index
    assert r.hit_pages == 2 and r.restored_pages == 1
    assert pc.tables[1][:2] == shared_pages
    assert all(pc.refcount[p] == 2 for p in shared_pages)


def test_restore_reports_gap_when_donor_evicted():
    pc = _stamped_cache()
    toks = list(range(8)) + [99, 98]
    assert pc.alloc_seq(0, 10, tokens=toks)
    pc.register_prefix(0, toks)
    assert pc.alloc_seq(1, 10, tokens=toks)
    rec = pc.offload(1)
    assert rec.shared_pages == 2
    pc.free_seq(0)                            # donor evicts: prefix gone
    r = pc.restore(1, toks)
    # pages [hit, snap_start) = [0, 2) hold nothing; caller must recompute
    assert r.hit_pages == 0 and r.snap_start_page == 2
    assert r.restored_pages == 1              # the private tail came back


def test_restore_returns_none_when_pool_exhausted():
    pc = _stamped_cache(num_pages=4)
    toks = list(range(10))
    assert pc.alloc_seq(0, 10, tokens=toks)
    rec = pc.offload(0)
    assert pc.alloc_seq(7, 16, tokens=list(range(100, 116)))  # eat the pool
    assert pc.restore(0, toks) is None        # no state change,
    assert pc.offloaded[0] is rec             # checkpoint kept for retry
    pc.free_seq(7)
    assert pc.restore(0, toks) is not None


def test_double_offload_and_drop():
    pc = _stamped_cache()
    assert pc.alloc_seq(0, 6, tokens=list(range(6)))
    pc.offload(0)
    with pytest.raises(ValueError, match="already offloaded"):
        pc.offload(0)
    assert pc.drop_offloaded(0) is not None
    assert pc.drop_offloaded(0) is None and not pc.offloaded


def test_prefix_hash_is_seeded_by_quant_mode():
    """Pages written under one KV-quant mode must never be served to a
    lookup under another: the prefix-hash chain is seeded by the quant
    config, so the same tokens give disjoint key sets (regression for the
    ROADMAP carry-over)."""
    from repro.serving.kv_quant import KVQuantConfig
    toks = list(range(16))
    args = dict(num_pages=8, page_size=4, n_layers=1, kv_heads=1, head_dim=2)
    fp = PagedCache(**args)
    fp2 = PagedCache(**args)
    q8 = PagedCache(kv_quant=KVQuantConfig(dtype="int8"), **args)
    bf = PagedCache(dtype=jnp.bfloat16, **args)
    assert fp._prefix_keys(toks) == fp2._prefix_keys(toks)  # deterministic
    assert not set(fp._prefix_keys(toks)) & set(q8._prefix_keys(toks))
    assert not set(fp._prefix_keys(toks)) & set(bf._prefix_keys(toks))
    assert not set(q8._prefix_keys(toks)) & set(bf._prefix_keys(toks))


# ------------------------------------------------- engine: priority preemption
@pytest.mark.parametrize("kvq", [None, "int8"], ids=["fp32", "int8"])
def test_preemption_round_trip_is_lossless(small_lm, kvq):
    """A high-priority arrival preempts the running low-priority request
    (pages offloaded to host); once capacity frees, the victim restores and
    finishes with greedy output identical to an unconstrained run."""
    cfg, model, params = small_lm
    pA, pB = _prompts(cfg, [24, 24], seed=3)

    roomy = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                         page_size=8, eos_id=-1, kv_quant=kvq,
                         speculation=_SPEC)
    ref = Engine(model, params, roomy).generate(
        [pA, pB], max_new_tokens=12, sampling=GREEDY)
    ref = {o.rid: o.output for o in ref}

    tight = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                         page_size=8, num_pages=6, eos_id=-1, kv_quant=kvq,
                         preemption=True, speculation=_SPEC)
    eng = Engine(model, params, tight)
    ra = eng.submit(pA, max_new_tokens=12, sampling=GREEDY, priority=0)
    for _ in range(4):                        # A decodes a few tokens first
        eng.step()
    rb = eng.submit(pB, max_new_tokens=12, sampling=GREEDY, priority=1)
    outs = _drain(eng)

    assert eng.stats.preemptions >= 1
    assert eng.stats.offloaded_pages > 0
    assert eng.stats.restored_pages > 0
    # host bytes match the analytic model (payload + scale pools)
    assert eng.stats.offloaded_bytes == MM.host_offload_bytes(
        cfg, eng.stats.offloaded_pages, 8, dtype=eng.cache_dtype,
        kv_quant=eng.kv_quant)
    assert outs[ra].output == ref[0], "victim's tokens changed"
    assert outs[rb].output == ref[1], "preemptor's tokens changed"
    assert outs[ra].finish_reason is FinishReason.LENGTH


def test_preemption_never_targets_equal_or_higher_priority(small_lm):
    cfg, model, params = small_lm
    pA, pB = _prompts(cfg, [24, 24], seed=4)
    conf = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                        page_size=8, num_pages=6, eos_id=-1, preemption=True,
                        speculation=_SPEC)
    eng = Engine(model, params, conf)
    ra = eng.submit(pA, max_new_tokens=8, sampling=GREEDY, priority=1)
    for _ in range(2):
        eng.step()
    rb = eng.submit(pB, max_new_tokens=8, sampling=GREEDY, priority=1)
    outs = _drain(eng)
    assert eng.stats.preemptions == 0         # equal priority: defer, not evict
    assert eng.stats.deferred_admissions > 0
    assert {outs[ra].finish_reason, outs[rb].finish_reason} == {
        FinishReason.LENGTH}


def test_abort_while_preempted_drops_checkpoint(small_lm):
    cfg, model, params = small_lm
    pA, pB = _prompts(cfg, [24, 24], seed=5)
    conf = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                        page_size=8, num_pages=6, eos_id=-1, preemption=True,
                        speculation=_SPEC)
    eng = Engine(model, params, conf)
    ra = eng.submit(pA, max_new_tokens=12, sampling=GREEDY, priority=0)
    for _ in range(4):
        eng.step()
    eng.submit(pB, max_new_tokens=12, sampling=GREEDY, priority=1)
    eng.step()                                # preempts A
    row = eng.sched.find_active(ra)
    assert row is None and ra in eng.pc.offloaded
    saved = next(r for r in eng.sched.waiting if r.rid == ra)
    assert saved.state is RequestState.PREEMPTED and saved.saved_output
    out = eng.abort(ra)
    assert out.finish_reason is FinishReason.ABORT
    assert out.output == saved.saved_output   # partial progress surfaced
    assert ra not in eng.pc.offloaded         # host checkpoint dropped
    _drain(eng)
    assert not eng.pc.offloaded and eng.pc.offloaded_bytes == 0


# ------------------------------------- engine: bounded admission + shedding
def test_bounded_admission_and_deadline_shed(small_lm):
    cfg, model, params = small_lm
    clk = ManualClock(100.0)
    conf = EngineConfig(batch_slots=1, max_len=64, cache="paged",
                        page_size=8, num_pages=5, eos_id=-1, max_queued=2,
                        default_queue_timeout_s=5.0, clock=clk,
                        preemption=False, speculation=_SPEC)
    eng = Engine(model, params, conf)
    ps = _prompts(cfg, [16] * 4, seed=6)
    r0 = eng.submit(ps[0], max_new_tokens=8, sampling=GREEDY)
    eng.step()                                # r0 occupies the only slot
    r1 = eng.submit(ps[1], max_new_tokens=8, sampling=GREEDY)
    r2 = eng.submit(ps[2], max_new_tokens=8, sampling=GREEDY,
                    queue_timeout_s=200.0)    # per-request override
    with pytest.raises(QueueFullError) as ei:
        eng.submit(ps[3], max_new_tokens=8, sampling=GREEDY)
    assert ei.value.retry_after_s > 0
    assert eng.stats.rejected_submits == 1

    clk.advance(10.0)                         # past r1's default deadline only
    outs = _drain(eng)
    assert outs[r1].finish_reason is FinishReason.SHED
    assert outs[r1].output == [] and outs[r1].ttft == 0.0
    assert outs[r0].finish_reason is FinishReason.LENGTH
    assert outs[r2].finish_reason is FinishReason.LENGTH  # override held
    assert eng.stats.shed_requests == 1


def test_preempted_request_is_never_shed(small_lm):
    """A preempted request already met its admission deadline and holds
    generated tokens — expiring the queue must not discard it."""
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    pA, pB = _prompts(cfg, [24, 24], seed=7)
    conf = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                        page_size=8, num_pages=6, eos_id=-1,
                        default_queue_timeout_s=1.0, clock=clk,
                        preemption=True, speculation=_SPEC)
    eng = Engine(model, params, conf)
    ra = eng.submit(pA, max_new_tokens=12, sampling=GREEDY, priority=0)
    for _ in range(4):
        eng.step()
    rb = eng.submit(pB, max_new_tokens=12, sampling=GREEDY, priority=1)
    eng.step()                                # preempts A, far past deadline
    clk.advance(100.0)
    outs = _drain(eng)
    assert eng.stats.preemptions >= 1
    assert outs[ra].finish_reason is FinishReason.LENGTH
    assert outs[rb].finish_reason is FinishReason.LENGTH
    assert eng.stats.shed_requests == 0


# ----------------------------------------------------------- fault injection
def test_fault_injector_page_seizure_defers_then_recovers(small_lm):
    cfg, model, params = small_lm
    inj = F.FaultInjector().exhaust_pages_at(0, 999).release_pages_at(6)
    conf = EngineConfig(batch_slots=2, max_len=64, cache="paged",
                        page_size=8, num_pages=6, eos_id=-1, faults=inj,
                        preemption=False, speculation=_SPEC)
    eng = Engine(model, params, conf)
    rid = eng.submit(_prompts(cfg, [16], seed=8)[0], max_new_tokens=4,
                     sampling=GREEDY)
    for _ in range(5):                        # pool fully seized: no admission
        eng.step()
    assert eng.sched.find_active(rid) is None
    assert eng.stats.deferred_admissions >= 5
    assert inj.seized_pages == 6
    outs = _drain(eng)                        # release fires at step 6
    assert inj.seized_pages == 0
    assert outs[rid].finish_reason is FinishReason.LENGTH
    kinds = [k for _, k, _ in inj.log]
    assert kinds == ["exhaust_pages", "release_pages"]


def test_fault_injector_mid_stream_abort(small_lm):
    cfg, model, params = small_lm
    inj = F.FaultInjector().abort_at(4, 0)
    conf = EngineConfig(batch_slots=2, max_len=64, cache="paged",
                        page_size=8, eos_id=-1, faults=inj,
                        speculation=_SPEC)
    eng = Engine(model, params, conf)
    rid = eng.submit(_prompts(cfg, [16], seed=9)[0], max_new_tokens=32,
                     sampling=GREEDY)
    _drain(eng)
    (step_no, kind, out), = inj.log           # abort's RequestOutput is
    assert kind == "abort" and step_no == 4   # returned through the log
    assert out.rid == rid
    assert out.finish_reason is FinishReason.ABORT
    assert 0 < len(out.output) < 32           # stopped mid-decode
    # everything released
    assert not eng.pc.tables and len(eng.pc.free_list) == eng.pc.num_pages


# ------------------------------------------------------------------ heartbeat
def test_heartbeat_staleness_observable_from_monitor():
    clk = ManualClock(0.0)
    hb = Heartbeat(timeout_s=10.0, clock=clk.now)
    assert hb.check() and hb.missed == 0
    clk.advance(25.0)                         # worker silent for 2.5 windows
    assert not hb.check()                     # monitor sees it without beat()
    assert hb.missed == 2
    assert hb.stale_s == 25.0 and not hb.healthy
    assert not hb.check() and hb.missed == 2  # re-check doesn't double-charge
    clk.advance(10.0)
    assert not hb.check() and hb.missed == 3
    hb.beat()                                 # worker recovers
    assert hb.check() and hb.missed == 3 and hb.healthy


def test_heartbeat_late_beat_still_counts_missed():
    clk = ManualClock(0.0)
    hb = Heartbeat(timeout_s=10.0, clock=clk.now)
    clk.advance(15.0)
    hb.beat()                                 # no monitor ever looked
    assert hb.missed == 1


# ------------------------------------------------------------------ HTTP layer
def _post(port, body, timeout=120):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/completions",
        data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, dict(r.headers), json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), json.loads(e.read())


@pytest.fixture()
def overload_server(small_lm):
    """Tiny engine whose page pool is seized up front: nothing ever admits,
    so HTTP requests exercise the queue-full / shed paths deterministically."""
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    inj = F.FaultInjector()
    eng = Engine(model, params, EngineConfig(
        batch_slots=1, max_len=64, cache="paged", page_size=8, num_pages=5,
        eos_id=-1, max_queued=1, clock=clk, preemption=False,
        speculation=_SPEC))
    inj.seize_pages(eng.pc, 5)
    srv = make_server(eng)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    yield cfg, srv, clk, inj, eng
    srv.shutdown()


def test_http_429_and_shed_503(overload_server):
    cfg, srv, clk, inj, eng = overload_server
    prompt = _prompts(cfg, [8], seed=10)[0]
    results = {}

    def queued_req():
        results["shed"] = _post(srv.port, {
            "prompt": prompt, "max_tokens": 4, "temperature": 0.0,
            "queue_timeout_s": 5.0})
    th = threading.Thread(target=queued_req, daemon=True)
    th.start()
    deadline = time.time() + 30
    while not eng.sched.waiting and time.time() < deadline:
        time.sleep(0.01)                      # wait until it is queued
    assert eng.sched.waiting

    # queue is at max_queued=1: next submit is rejected with Retry-After
    st, hdr, body = _post(srv.port, {"prompt": prompt, "max_tokens": 4,
                                     "temperature": 0.0})
    assert st == 429
    assert int(hdr["Retry-After"]) >= 1
    assert body["error"]["type"] == "overloaded_error"

    clk.advance(10.0)                         # expire the queued deadline
    th.join(timeout=60)
    assert not th.is_alive(), "shed request's HTTP response never arrived"
    st, hdr, body = results["shed"]
    assert st == 503
    assert "Retry-After" in hdr
    assert "shed" in body["error"]["message"]


def test_http_watchdog_fails_stalled_streams(small_lm):
    """A stalled engine step must not hang clients: the watchdog observes
    the missed heartbeat (through the injected clock) and terminates every
    in-flight request with FinishReason.STALL."""
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    inj = F.FaultInjector()

    def stall():                              # simulate a wedged step: jump
        clk.advance(99.0)                     # past the watchdog timeout and
        time.sleep(0.4)                       # hold the worker long enough
                                              # (real time) to be observed
    inj.stall_at(2, stall)
    eng = Engine(model, params, EngineConfig(
        batch_slots=2, max_len=64, cache="paged", page_size=8, eos_id=-1,
        clock=clk, faults=inj, speculation=_SPEC))
    srv = make_server(eng, stall_timeout_s=10.0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        prompt = _prompts(cfg, [8], seed=11)[0]
        st, _hdr, body = _post(srv.port, {"prompt": prompt, "max_tokens": 40,
                                          "temperature": 0.0})
        assert st == 503
        assert "stall" in body["error"]["message"]
        assert srv.worker.stalled_requests >= 1
        assert srv.worker.heartbeat.missed >= 1
    finally:
        srv.shutdown()


# ------------------------------------------------------- overload accounting
def test_overload_counters_account_for_every_request(small_lm):
    """Synthetic overload burst: every submitted request is accounted for —
    finished, shed, or rejected — and the §14 counters are all exercised."""
    cfg, model, params = small_lm
    clk = ManualClock(0.0)
    conf = EngineConfig(batch_slots=4, max_len=96, cache="paged",
                        page_size=8, num_pages=7, eos_id=-1, max_queued=3,
                        default_queue_timeout_s=6.0, clock=clk,
                        preemption=True, speculation=_SPEC)
    eng = Engine(model, params, conf)
    prompts = _prompts(cfg, [24] * 6, seed=12)
    accepted, rejected = [], 0
    # low-priority occupant first, then a burst of mixed priorities
    accepted.append(eng.submit(prompts[0], max_new_tokens=10,
                               sampling=GREEDY, priority=0))
    for _ in range(3):
        eng.step()
        clk.advance(1.0)
    for i, p in enumerate(prompts[1:], start=1):
        try:
            accepted.append(eng.submit(
                p, max_new_tokens=10, sampling=GREEDY, priority=i % 2))
        except QueueFullError:
            rejected += 1
    outs = {}
    steps = 0
    while not eng.sched.idle and steps < 400:
        for o in eng.step():
            outs[o.rid] = o
        eng._events.clear()
        clk.advance(1.0)
        steps += 1
    s = eng.stats
    assert rejected == s.rejected_submits and rejected > 0
    assert set(outs) == set(accepted), "a request vanished"
    n_shed = sum(o.finish_reason is FinishReason.SHED for o in outs.values())
    n_done = sum(o.finish_reason is FinishReason.LENGTH
                 for o in outs.values())
    assert n_shed == s.shed_requests
    assert n_done + n_shed == len(accepted)
    assert s.preemptions > 0 and s.offloaded_pages > 0
    assert s.restored_pages > 0 and s.offloaded_bytes > 0
    assert s.deferred_admissions > 0
    assert not eng.pc.offloaded, "an offloaded checkpoint leaked"
    assert len(eng.pc.free_list) == eng.pc.num_pages, "pages leaked"
