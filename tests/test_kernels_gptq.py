"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes and all
paper strategies — this is the reproduction of the paper's Tables I/II claim
(the optimizations are numerics-preserving)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import gptq, packing
from repro.core.opt_strategies import STRATEGIES, get_strategy
from repro.kernels import ops, ref


def _make_quant(k, n, g, seed=0, act_order=False, bias=False):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.5, size=(k, n)).astype(np.float32))
    h = None
    if act_order:
        x = rng.normal(size=(256, k)).astype(np.float32)
        h = jnp.asarray(2 * x.T @ x)
    b = jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) if bias else None
    ql = gptq.gptq_quantize(w, h, gptq.GPTQConfig(group_size=g, act_order=act_order),
                            bias=b)
    return w, ql


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_all_strategies_match_oracle(strategy):
    k, n, g, m = 256, 128, 64, 16
    w, ql = _make_quant(k, n, g)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(m, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y_k = ops.gptq_linear(ql, x, strategy=get_strategy(strategy), use_pallas=True,
                          block_sizes=(8, 64, 64))
    # 'naive' materializes W as bf16 in HBM (that IS the strategy) -> bf16 tol
    atol = 1e-1 if strategy == "naive" else 2e-2
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-2, atol=atol)


@pytest.mark.parametrize("m,k,n,g,bm,bn,bk", [
    (1, 128, 64, 32, 8, 64, 32),      # GEMV decode, bk == g
    (8, 256, 128, 128, 8, 128, 128),  # one group per block
    (32, 512, 256, 128, 16, 128, 256),# two groups per block
    (5, 128, 64, -1, 8, 64, 128),     # single whole-K group, odd M (padding)
    (16, 256, 64, 64, 8, 64, 64),
])
def test_shape_sweep_opt4gptq(m, k, n, g, bm, bn, bk):
    w, ql = _make_quant(k, n, g, seed=m)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(m, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y_k = ops.gptq_linear(ql, x, use_pallas=True, block_sizes=(bm, bn, bk))
    assert y_k.shape == (m, n)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    k, n, g = 128, 64, 32
    w, ql = _make_quant(k, n, g, seed=7)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(4, k)), dtype=dtype)
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y_k = ops.gptq_linear(ql, x, use_pallas=True, block_sizes=(8, 64, 64))
    assert y_k.dtype == dtype
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_act_order_with_kernel():
    k, n, g = 128, 64, 32
    w, ql = _make_quant(k, n, g, seed=9, act_order=True)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(4, k)).astype(np.float32))
    y_true = x @ w
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y_k = ops.gptq_linear(ql, x, use_pallas=True, block_sizes=(8, 64, 32))
    # kernel must agree with the oracle (perm handled identically)...
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=2e-2, atol=2e-2)
    # ...and 4-bit quantization error vs the fp truth stays bounded
    rel = float(jnp.linalg.norm(y_k - y_true) / jnp.linalg.norm(y_true))
    assert rel < 0.15, rel


def test_bias_and_batch_dims():
    k, n, g = 128, 64, 64
    w, ql = _make_quant(k, n, g, seed=11, bias=True)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 3, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y_k = ops.gptq_linear(ql, x, use_pallas=True, block_sizes=(8, 64, 64))
    assert y_k.shape == (2, 3, n)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=2e-2, atol=2e-2)


def test_strategies_numerics_preserving_pairwise():
    """Paper Tables I/II: every opt variant produces (near-)identical outputs."""
    k, n, g, m = 256, 128, 128, 8
    w, ql = _make_quant(k, n, g, seed=13)
    x = jnp.asarray(np.random.default_rng(6).normal(size=(m, k)).astype(np.float32))
    outs = {name: np.asarray(ops.gptq_linear(ql, x, strategy=get_strategy(name),
                                             use_pallas=True, block_sizes=(8, 128, 128)))
            for name in sorted(STRATEGIES)}
    base = outs["baseline"]
    for name, y in outs.items():
        atol = 1e-1 if name == "naive" else 2e-2  # naive pays a bf16 HBM roundtrip
        np.testing.assert_allclose(y, base, rtol=2e-2, atol=atol,
                                   err_msg=f"strategy {name} diverged")


@pytest.mark.parametrize("k,bk,g", [(256, 64, 32), (512, 64, 32),
                                    (512, 128, 64)])
def test_scale_block_indexing_many_k_blocks(k, bk, g):
    """Regression: with ``bk > group_size`` and more than two K blocks the
    scales/qzeros BlockSpec index maps must advance one gk-row block per K
    step.  The old element-offset form (``ki*bk//g``) double-counted the
    block height and read the wrong group rows; interpret-mode index
    clamping hid it whenever K spanned <= 2 blocks."""
    w, ql = _make_quant(k, 128, g, seed=21)
    x = jnp.asarray(np.random.default_rng(7).normal(size=(17, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    for name in ("opt4gptq", "naive"):  # naive covers the dequant-pass specs
        y_k = ops.gptq_linear(ql, x, strategy=get_strategy(name),
                              use_pallas=True, block_sizes=(8, 64, bk))
        atol = 1e-1 if name == "naive" else 2e-2
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                                   rtol=2e-2, atol=atol,
                                   err_msg=f"strategy {name}")


@given(st.integers(1, 3), st.integers(1, 2), st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_random_shapes(mw, nw, seed):
    m, k, n, g = mw * 4 + 1, 128, nw * 64, 64
    w, ql = _make_quant(k, n, g, seed=seed)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(m, k)).astype(np.float32))
    y_ref = ops.gptq_linear(ql, x, use_pallas=False)
    y_k = ops.gptq_linear(ql, x, use_pallas=True, block_sizes=(8, 64, 64))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=2e-2, atol=2e-2)
