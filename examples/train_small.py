"""End-to-end training driver: train a ~100M-class model for a few hundred
steps with the full production substrate — sharded optimizer, remat, grad
accumulation, async checkpointing, and crash-resume.

  PYTHONPATH=src python examples/train_small.py --steps 200
(defaults are scaled down so CPU finishes in minutes; pass --d-model 768
 --layers 12 for a true ~100M run if you have time)
"""
import argparse
import dataclasses
import pathlib
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import LMDataPipeline
from repro.models import build_model
from repro.runtime.fault_tolerance import resilient_train_loop
from repro.training import optimizer as O
from repro.training.train_loop import init_train_state, make_train_step


def main(steps: int, d_model: int, layers: int, ckpt_dir: str | None):
    cfg = dataclasses.replace(
        get_config("qwen3_4b"),
        num_layers=layers, d_model=d_model, num_heads=max(d_model // 64, 1),
        num_kv_heads=max(d_model // 128, 1), head_dim=64,
        d_ff=d_model * 4, vocab_size=4096, dtype="float32", remat="full")
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"training {cfg.name}-style model: {layers}L d={d_model} "
          f"~{n / 1e6:.1f}M params")

    opt = O.OptimizerConfig(learning_rate=1e-3, warmup_steps=20,
                            total_steps=steps)
    state = init_train_state(model, opt, jax.random.key(0))
    step_fn = jax.jit(make_train_step(model, opt, accum_steps=2))
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                          global_batch=8, seed=0)

    d = ckpt_dir or tempfile.mkdtemp(prefix="train_small_")
    ck = Checkpointer(d, keep=2)
    to_batch = lambda b: {k: jnp.asarray(v) for k, v in b.items()}

    t0 = time.time()
    state, log, start = resilient_train_loop(
        step_fn, state, pipe, steps=steps, ckpt=ck, ckpt_every=25,
        async_ckpt=True, to_batch=to_batch)
    dt = time.time() - t0
    print(f"resumed from step {start}; ran to {steps} in {dt:.1f}s "
          f"({(steps - start) * pipe.global_batch * 64 / dt:.0f} tok/s)")
    first, last = log[0], log[-1]
    print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"(grad_norm {last['grad_norm']:.3f}, lr {last['lr']:.2e})")
    print(f"checkpoints: {ck.all_steps()} in {d}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    a = ap.parse_args()
    main(a.steps, a.d_model, a.layers, a.ckpt_dir)
