"""End-to-end GPTQ pipeline: train a ~small LM a few hundred steps, calibrate
Hessians on real activations, quantize with GPTQ (vs RTN), compare held-out
perplexity, and checkpoint the quantized model.

  PYTHONPATH=src python examples/quantize_model.py [--steps 150]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.quantize_model import quantize_params
from repro.data.pipeline import LMDataPipeline
from repro.models import build_model
from repro.models import layers as L
from repro.training import optimizer as O
from repro.training.train_loop import init_train_state, make_train_step


def main(steps: int = 120):
    # unscanned layers so the calibration capture sees per-layer names
    cfg = dataclasses.replace(smoke_config("llama2_7b")
                              if False else smoke_config("qwen3_4b"),
                              scan_layers=False)
    model = build_model(cfg)
    opt = O.OptimizerConfig(learning_rate=2e-3, warmup_steps=10,
                            total_steps=steps)
    state = init_train_state(model, opt, jax.random.key(0))
    step_fn = jax.jit(make_train_step(model, opt))
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, seed=11)

    print(f"training {cfg.name} for {steps} steps ...")
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        state, m = step_fn(state, batch)
        if s % 25 == 0 or s == steps - 1:
            print(f"  step {s:4d} loss {float(m['loss']):.4f}")

    print("calibrating Hessians on 4 batches ...")
    with L.capture_hessians() as ctx:
        for s in range(4):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            model.apply(state.params, batch, mode="train")
    print(f"  captured {len(ctx.hessians)} linear layers")

    q_gptq = quantize_params(state.params, dict(ctx.hessians),
                             GPTQConfig(group_size=32, act_order=False))
    q_rtn = quantize_params(state.params, None, GPTQConfig(group_size=32))

    def ppl(params):
        tot = 0.0
        for s in range(4):
            b = {k: jnp.asarray(v) for k, v in pipe.batch_at(10_000 + s).items()}
            tot += float(model.loss_fn(params, b)[0])
        return float(np.exp(tot / 4))

    p_fp, p_g, p_r = ppl(state.params), ppl(q_gptq), ppl(q_rtn)
    print(f"held-out ppl: fp32 {p_fp:.3f} | GPTQ-int4 {p_g:.3f} | RTN-int4 {p_r:.3f}")
    print(f"GPTQ degradation {100 * (p_g / p_fp - 1):.2f}% vs RTN {100 * (p_r / p_fp - 1):.2f}%")

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(0, q_gptq)
        restored, _ = ck.restore(q_gptq)
        print(f"quantized checkpoint round-trip OK -> {ck.latest_step()=}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    main(ap.parse_args().steps)
