"""Quickstart: quantize a small LM with GPTQ and serve it through the engine
with the paper's full Opt4GPTQ kernel strategy.

  PYTHONPATH=src python examples/quickstart.py

For multi-token decode steps, pass
``EngineConfig(speculation=SpecConfig(method="ngram", k=8))`` (or the
``--speculate ngram`` launcher flag) — speculative decoding is
token-identical under greedy; see DESIGN.md §16 and examples/serve_gptq.py.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import OPT4GPTQ
from repro.core.quantize_model import quantize_params
from repro.models import build_model
from repro.models import layers as L
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine


def main():
    # 1. build a reduced qwen3-family model (same code path as the 110B dry-run)
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model}) "
          f"params={sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")

    # 2. GPTQ-quantize every projection to 4 bits (RTN+error-feedback without
    #    calibration; see examples/quantize_model.py for Hessian calibration)
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    orig = sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))
    quant = sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(qparams))
    print(f"bytes: fp32 {orig:,} -> quantized {quant:,} ({orig / quant:.2f}x)")

    # 3. serve with continuous batching + the Opt4GPTQ Pallas kernel
    kernels = L.KernelConfig(strategy=OPT4GPTQ, use_pallas=True,
                             block_sizes=(8, 64, 64))
    eng = Engine(model, qparams, EngineConfig(
        batch_slots=4, max_len=64, kernels=kernels, eos_id=-1))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (5, 9, 3)]
    done = eng.generate(prompts, max_new_tokens=8)
    for f in sorted(done, key=lambda f: f.rid):
        print(f"request {f.rid}: prompt_len={f.prompt_len} -> {f.output} "
              f"({f.finish_reason.value}, ttft {f.ttft * 1e3:.0f}ms)")
    print(f"generated {eng.stats.tokens_generated} tokens in "
          f"{eng.stats.steps} engine steps "
          f"({eng.stats.tokens_per_step:.2f} tokens/step)")


if __name__ == "__main__":
    main()
