"""Serve a GPTQ-quantized model under a ShareGPT-like request stream with
continuous batching — the paper's vLLM workload in miniature — and compare
kernel strategies end to end.

  PYTHONPATH=src python examples/serve_gptq.py [--requests 10] [--arch qwen3_4b]

To run the same engine as an HTTP service and scrape it (DESIGN.md §15):

  # terminal 1: OpenAI-style server + observability surface
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --serve --port 8000 --stall-timeout 30 --trace-out trace.json

  # terminal 2: a completion, then a Prometheus scrape and a health probe
  curl -s localhost:8000/v1/completions -d \
      '{"prompt": [2, 3, 4, 5], "max_tokens": 8, "temperature": 0.0}'
  curl -s localhost:8000/metrics    # text exposition: engine_*_total,
                                    # engine_ttft_seconds buckets, ...
  curl -s localhost:8000/healthz    # {"status": "ok", "watchdog": "armed",
                                    #  "heartbeat_stale_s": ...}

On shutdown (Ctrl-C) the server writes ``trace.json`` — open it at
https://ui.perfetto.dev to see per-request lifecycle spans and engine
step spans.

Speculative decoding + warm prefix cache (DESIGN.md §16): add
``--speculate ngram --spec-k 8`` (or ``--speculate draft --draft-arch
qwen3_4b``) for multi-token decode steps — greedy output is
token-identical, and the driver log reports the acceptance rate and
accepted tokens per verify step — and ``--prefix-cache DIR`` to persist
the hashed prefix index across restarts (saved on exit, adopted at
startup; paged cache only).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import STRATEGIES
from repro.core.quantize_model import quantize_params
from repro.data.pipeline import sharegpt_stream
from repro.models import build_model
from repro.models import layers as L
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine
from repro.serving.sampler import SamplingParams


def main(n_requests: int = 10, arch: str = "qwen3_4b"):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    stream = sharegpt_stream(n_requests, vocab_size=cfg.vocab_size, seed=1,
                             mean_prompt=12, mean_output=6, max_prompt=48)

    for strat in ("baseline", "opt4gptq"):
        kern = L.KernelConfig(strategy=STRATEGIES[strat], use_pallas=True,
                              block_sizes=(8, 64, 64))
        eng = Engine(model, qparams, EngineConfig(
            batch_slots=4, max_len=128, kernels=kern, eos_id=-1))
        t0 = time.time()
        for r in stream:
            eng.submit(r.prompt, max_new_tokens=r.output_len,
                       sampling=SamplingParams(greedy=True))
        done = eng.run()
        dt = time.time() - t0
        toks = sum(len(f.output) for f in done)
        lat = [f.latency for f in done]
        ttft = [f.ttft for f in done]
        # single-token outputs have no decode phase -> no tpot sample
        tpot = [f.tpot for f in done if f.tpot > 0]
        tpot_ms = np.percentile(tpot, 50) * 1e3 if tpot else 0.0
        print(f"[{strat:9s}] {len(done)} reqs | {toks} tokens | "
              f"{toks / dt:7.2f} tok/s (interpret) | "
              f"p50 latency {np.percentile(lat, 50):.2f}s "
              f"p99 {np.percentile(lat, 99):.2f}s | "
              f"p50 ttft {np.percentile(ttft, 50):.2f}s "
              f"p50 tpot {tpot_ms:.0f}ms")
    print("note: interpret-mode wall time validates the harness; TPU "
          "performance comes from the analytic model (benchmarks).")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--arch", default="qwen3_4b",
                    help="any registered arch (smoke-reduced), e.g. "
                         "qwen3_4b, llama3_8b")
    args = ap.parse_args()
    main(args.requests, args.arch)
