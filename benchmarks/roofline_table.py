"""Roofline table from the dry-run result cache (experiments/dryrun/*.json):
one row per (arch x shape x mesh) cell — the EXPERIMENTS.md §Roofline source."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records():
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def run():
    lines = []
    ok = skip = fail = 0
    for r in load_records():
        if r["status"] == "skipped":
            skip += 1
            continue
        if r["status"] == "failed":
            fail += 1
            lines.append(f"dryrun/{r['cell']},0,FAILED")
            continue
        ok += 1
        ro = r["roofline"]
        lines.append(
            f"roofline/{r['cell']},0,"
            f"compute_s={ro['compute_s']:.4f}|mem_s={ro['memory_s']:.4f}|"
            f"coll_s={ro['collective_s']:.4f}|dom={ro['dominant']}|"
            f"useful={ro['useful_ratio']:.3f}|mem_gb={r['memory']['total_gb']:.2f}|"
            f"fits={int(r['memory']['fits_16gb'])}")
    lines.append(f"dryrun/summary,0,ok={ok}|skipped={skip}|failed={fail}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
