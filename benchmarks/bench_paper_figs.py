"""Paper Figs. 2-3 reproduction: per-model x per-strategy throughput / latency.

Two layers of evidence (CPU container => no wall-clock TPU truth):
  * modeled — the analytic v5e performance model (core/perf_model.py) charging
    exactly the bytes/compute each strategy changes; this is the number
    compared against the paper's reported gains in EXPERIMENTS.md.
  * measured — the real serving engine running the real Pallas kernels
    (interpret mode) on reduced configs; validates the HARNESS, not TPU time.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.paper_models import PAPER_MODELS, PAPER_ORDER
from repro.core.opt_strategies import STRATEGIES
from repro.core.perf_model import request_latency, serving_throughput

STRATS = ["baseline", "smb", "vml", "ila", "opt4gptq"]

# paper's reported % gains (Fig. 2 throughput, Fig. 3 latency reduction)
PAPER_FIG2 = {
    "qwen1p5_4b_chat": {"smb": 6.83, "vml": 3.11, "ila": 28.74, "opt4gptq": 41.77},
    "qwen1p5_1p8b_chat": {"smb": 4.94, "vml": 1.36, "ila": 16.75, "opt4gptq": 21.93},
    "llama_13b": {"smb": 17.98, "vml": 11.03, "ila": 57.19, "opt4gptq": 84.42},
    "codellama_7b": {"smb": 14.74, "vml": 5.88, "ila": 46.30, "opt4gptq": 67.55},
    "llama2_7b": {"smb": 9.50, "vml": 4.91, "ila": 37.26, "opt4gptq": 54.55},
    "llama3_8b": {"smb": 16.43, "vml": 5.89, "ila": 44.81, "opt4gptq": 61.78},
}
PAPER_FIG3 = {
    "qwen1p5_4b_chat": {"smb": 5.21, "vml": 1.93, "ila": 30.91, "opt4gptq": 47.96},
    "qwen1p5_1p8b_chat": {"smb": 4.62, "vml": 2.67, "ila": 19.42, "opt4gptq": 25.18},
    "llama_13b": {"smb": 12.41, "vml": 1.21, "ila": 36.97, "opt4gptq": 51.35},
    "codellama_7b": {"smb": 11.86, "vml": 2.33, "ila": 36.98, "opt4gptq": 49.73},
    "llama2_7b": {"smb": 11.39, "vml": 2.39, "ila": 37.00, "opt4gptq": 49.81},
    "llama3_8b": {"smb": 7.48, "vml": 0.55, "ila": 31.18, "opt4gptq": 41.23},
}


def modeled_tables():
    rows = []
    for mid in PAPER_ORDER:
        cfg = PAPER_MODELS[mid]
        base_tp = serving_throughput(cfg, strategy=STRATEGIES["baseline"])
        base_lat = request_latency(cfg, strategy=STRATEGIES["baseline"])
        for s in STRATS[1:]:
            tp = serving_throughput(cfg, strategy=STRATEGIES[s])
            lat = request_latency(cfg, strategy=STRATEGIES[s])
            rows.append({
                "model": mid, "strategy": s,
                "modeled_tp_gain_pct": (tp / base_tp - 1) * 100,
                "paper_tp_gain_pct": PAPER_FIG2[mid][s],
                "modeled_lat_red_pct": (1 - lat / base_lat) * 100,
                "paper_lat_red_pct": PAPER_FIG3[mid][s],
            })
    return rows


def measured_engine_throughput(n_requests: int = 6, max_new: int = 4):
    """Engine tokens/s on a reduced model per strategy (interpret-mode Pallas).
    Wall-clock here is CPU-interpreter time — harness validation only."""
    from repro.configs import smoke_config
    from repro.core.gptq import GPTQConfig
    from repro.core.quantize_model import quantize_params
    from repro.models import build_model
    from repro.models import layers as L
    from repro.serving.api import EngineConfig
    from repro.serving.engine import Engine

    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    rng = np.random.default_rng(0)
    out = []
    for s in ["baseline", "opt4gptq"]:
        kern = L.KernelConfig(strategy=STRATEGIES[s], use_pallas=True,
                              block_sizes=(8, 64, 64))
        eng = Engine(model, qparams, EngineConfig(
            batch_slots=4, max_len=64, kernels=kern, eos_id=-1))
        for _ in range(n_requests):
            eng.submit(rng.integers(2, cfg.vocab_size, size=8).tolist(),
                       max_new_tokens=max_new)
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        toks = sum(len(f.output) for f in done)
        out.append({"strategy": s, "tokens": toks, "wall_s": dt,
                    "tok_per_s_interpret": toks / dt})
    return out


def run(csv=True):
    rows = modeled_tables()
    lines = []
    for r in rows:
        lines.append(
            f"fig2_3/{r['model']}/{r['strategy']},0,"
            f"tp_gain={r['modeled_tp_gain_pct']:.1f}%"
            f"(paper {r['paper_tp_gain_pct']:.1f}%)|"
            f"lat_red={r['modeled_lat_red_pct']:.1f}%"
            f"(paper {r['paper_lat_red_pct']:.1f}%)")
    eng = measured_engine_throughput()
    for r in eng:
        lines.append(f"engine_measured/{r['strategy']},"
                     f"{r['wall_s'] * 1e6 / max(r['tokens'], 1):.0f},"
                     f"tok_s_interpret={r['tok_per_s_interpret']:.2f}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
