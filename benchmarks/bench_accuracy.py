"""Paper Tables I-II analogue: accuracy preservation under quantization and
under every kernel strategy.

Offline (no ARC dataset), the paper's two claims are reproduced as:
  1. GPTQ-int4 ~ fp16 quality: train a small LM on synthetic data, quantize
     (GPTQ with captured Hessians vs RTN), compare held-out perplexity.
  2. kernel strategies are numerics-preserving: greedy-decode agreement and
     max |logit delta| between every strategy and the baseline kernel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import STRATEGIES
from repro.core.quantize_model import quantize_params
from repro.data.pipeline import LMDataPipeline
from repro.models import build_model
from repro.models import layers as L
from repro.training import optimizer as O
from repro.training.train_loop import init_train_state, make_train_step


def _train_small(arch="qwen3_4b", steps=60, seq=32, batch=8):
    cfg = dataclasses.replace(smoke_config(arch), scan_layers=False)
    model = build_model(cfg)
    opt = O.OptimizerConfig(learning_rate=2e-3, warmup_steps=5, total_steps=steps)
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt))
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch, seed=3)
    for s in range(steps):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in pipe.batch_at(s).items()})
    return cfg, model, state.params, pipe, float(m["loss"])


def _ppl(model, params, pipe, *, kernels=L.DEFAULT_KERNELS, n_batches=4,
         offset=10_000):
    tot, cnt = 0.0, 0
    for s in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(offset + s).items()}
        loss, _ = model.loss_fn(params, b, kernels=kernels)
        tot += float(loss)
        cnt += 1
    return float(np.exp(tot / cnt))


def run():
    lines = []
    cfg, model, params, pipe, final_loss = _train_small()

    # --- claim 1: quantization quality (ppl: fp16 vs GPTQ vs RTN) ----------
    with L.capture_hessians() as ctx:
        for s in range(4):
            b = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            model.apply(params, b, mode="train")
    hessians = dict(ctx.hessians)
    q_gptq = quantize_params(params, hessians, GPTQConfig(group_size=32))
    q_rtn = quantize_params(params, None, GPTQConfig(group_size=32))

    ppl_fp = _ppl(model, params, pipe)
    ppl_gptq = _ppl(model, q_gptq, pipe)
    ppl_rtn = _ppl(model, q_rtn, pipe)
    lines.append(f"accuracy/ppl_fp16,0,{ppl_fp:.3f}")
    lines.append(f"accuracy/ppl_gptq_int4,0,{ppl_gptq:.3f}")
    lines.append(f"accuracy/ppl_rtn_int4,0,{ppl_rtn:.3f}")
    lines.append(f"accuracy/gptq_vs_fp16_ppl_ratio,0,{ppl_gptq / ppl_fp:.4f}")

    # hessian-weighted reconstruction error (GPTQ's objective) on the layer
    # with the most anisotropic Hessian — where error feedback matters
    from repro.core.gptq import gptq_quantize, quantization_error
    name = max(hessians, key=lambda k: float(
        jnp.std(jnp.diagonal(hessians[k])) / (jnp.mean(jnp.diagonal(hessians[k])) + 1e-9)))
    layer_idx = int(name.split(".")[0].removeprefix("layer"))
    proj = name.split(".")[-1]
    w = None
    for p, leaf in jax.tree_util.tree_leaves_with_path(params):
        ps = "/".join(str(getattr(e, "key", e)) for e in p)
        # params are scan-stacked: (L, K, N); slice the captured layer
        if f"/{proj}/" in ps and getattr(leaf, "ndim", 0) == 3 \
                and leaf.shape[1] == hessians[name].shape[0]:
            w = leaf[layer_idx]
            break
    if w is not None:
        h = hessians[name]
        eg = float(quantization_error(w, gptq_quantize(
            w, h, GPTQConfig(group_size=32)), h))
        er = float(quantization_error(w, gptq_quantize(
            w, None, GPTQConfig(group_size=32)), h))
        lines.append(f"accuracy/hessian_err_gptq,0,{eg:.6f}")
        lines.append(f"accuracy/hessian_err_rtn_ef,0,{er:.6f}")
        lines.append(f"accuracy/gptq_improves_hessian_err,0,{int(eg <= er * 1.001)}")

    # --- claim 2: strategies numerics-preserving (Tables I/II role) --------
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (4, 24)), jnp.int32)
    outs = {}
    for s, strat in STRATEGIES.items():
        kern = L.KernelConfig(strategy=strat, use_pallas=True,
                              block_sizes=(8, 64, 64))
        logits, _, _ = model.apply(q_gptq, {"tokens": toks}, kernels=kern,
                                   mode="prefill")
        outs[s] = np.asarray(logits, np.float32)
    base = outs["baseline"]
    base_arg = base.argmax(-1)
    for s, lg in outs.items():
        agree = float((lg.argmax(-1) == base_arg).mean())
        mad = float(np.abs(lg - base).max())
        lines.append(f"accuracy/strategy_{s}_greedy_agreement,0,{agree:.4f}")
        lines.append(f"accuracy/strategy_{s}_max_logit_delta,0,{mad:.4f}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
