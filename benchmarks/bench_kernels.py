"""Kernel microbench: per-strategy interpret-mode wall time (harness check)
plus the modeled v5e bytes/time per strategy for the paper's canonical GEMM
shapes — the decode fast lane (ISSUE 1): for the decode-GEMV shape every
strategy is timed on the seed's fixed-block general-matmul path AND on the
GEMV lane with autotuned blocks, so the speedup is tracked per PR — and the
paged-KV decode attention (ISSUE 2): the Pallas paged-attention kernel vs the
jnp block-table gather reference vs the slot layout's contiguous grouped
attend, at the same batch/context shape.

Emits CSV lines through benchmarks/run.py and writes the structured record
to BENCH_kernels.json at the repo root (the perf trajectory for later PRs).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gptq, packing
from repro.core.opt_strategies import STRATEGIES
from repro.core.perf_model import gptq_matmul_cost
from repro.kernels import autotune, ops
from repro.kernels import gptq_matmul as _gm
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref

SHAPES = [
    ("decode_gemv", 8, 1024, 1024, 128),
    ("prefill_gemm", 128, 1024, 512, 128),
]
SEED_BLOCKS = (8, 256, 256)       # the seed's fixed decode path
REPS = 3
JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_kernels.json")


def _time(fn, reps=REPS):
    """us per call, best-of-reps — same timer the autotuner selects with
    (autotune._time_call), so benchmark numbers and tuning decisions agree."""
    return autotune._time_call(fn, reps=reps) * 1e6


# decode attention shape: batch rows x GQA heads over a paged 128-token context
PAGED_SHAPE = dict(b=4, h=8, hkv=2, d=64, page_size=16, max_pages=8)


def _bench_paged_decode(lines, records):
    """Paged-vs-slot decode attention (ISSUE 2): the serving-side complement
    of the GEMV lane.  Slot baseline is the contiguous grouped-GQA attend the
    slot engine decodes with; the paged rows pay the block-table gather."""
    from repro.models.attention import attend

    p = PAGED_SHAPE
    b, h, hkv, d = p["b"], p["h"], p["hkv"], p["d"]
    ps, maxp = p["page_size"], p["max_pages"]
    ctx = ps * maxp
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(b * maxp + 1, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(b * maxp + 1, ps, hkv, d)), jnp.float32)
    bt = jnp.asarray(1 + rng.permutation(b * maxp).reshape(b, maxp), jnp.int32)
    lens = jnp.full((b,), ctx, jnp.int32)
    kc = jnp.asarray(rng.normal(size=(b, ctx, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, ctx, hkv, d)), jnp.float32)

    @jax.jit
    def slot_decode(q, kc, vc, lens):
        return attend(q[:, None], kc, vc, qpos=(lens - 1)[:, None],
                      causal=True, grouped=True)

    us_slot = _time(lambda: slot_decode(q, kc, vc, lens))
    us_kernel = _time(lambda: paged_attention(q, kp, vp, bt, lens))
    ref = jax.jit(paged_attention_ref)
    us_ref = _time(lambda: ref(q, kp, vp, bt, lens))
    rec = {"shape": "paged_decode", **p, "context": ctx,
           "us_slot_attend": us_slot, "us_paged_kernel": us_kernel,
           "us_paged_ref": us_ref,
           "paged_vs_slot": us_kernel / us_slot if us_slot else 0.0}
    records.append(rec)
    lines.append(
        f"kernel/paged_decode,{us_kernel:.0f},"
        f"slot_us={us_slot:.0f}|ref_us={us_ref:.0f}|"
        f"ctx={ctx}|ratio_vs_slot={rec['paged_vs_slot']:.2f}")


def run():
    lines = []
    records = []
    rng = np.random.default_rng(0)
    for name, m, k, n, g in SHAPES:
        w = jnp.asarray(rng.normal(0, 0.5, (k, n)).astype(np.float32))
        ql = gptq.gptq_quantize(w, None, gptq.GPTQConfig(group_size=g))
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        decode = name.startswith("decode")
        for s, strat in STRATEGIES.items():
            cost = gptq_matmul_cost(m, k, n, group_size=g, strategy=strat)
            rec = {"shape": name, "m": m, "k": k, "n": n, "group_size": g,
                   "strategy": s, "model_us": cost.time_s * 1e6,
                   "hbm_kb": cost.hbm_bytes / 1e3}
            if decode:
                # seed path: fixed blocks through the general tiled matmul
                qw = (ql.qweight if strat.packed_loads
                      else packing.unpack_int4_rows(ql.qweight, k))
                bm, bn, bk = SEED_BLOCKS
                us_seed = _time(lambda: _gm.gptq_matmul(
                    x, qw, ql.scales, ql.qzeros, group_size=g, strategy=strat,
                    bm=bm, bn=bn, bk=bk))
                # fast lane: GEMV dispatch, fixed blocks vs autotuned blocks
                us_fixed = _time(lambda: ops.gptq_linear(
                    ql, x, strategy=strat, use_pallas=True,
                    block_sizes=SEED_BLOCKS))
                tuned = autotune.get_block_sizes(m, k, n, g, strat)
                us_auto = _time(lambda: ops.gptq_linear(
                    ql, x, strategy=strat, use_pallas=True,
                    block_sizes="auto"))
                rec.update(us_seed_matmul=us_seed, us_gemv_fixed=us_fixed,
                           us_gemv_auto=us_auto, auto_blocks=list(tuned),
                           speedup_vs_seed=us_seed / us_auto if us_auto else 0)
                lines.append(
                    f"kernel/{name}/{s},{us_auto:.0f},"
                    f"seed_us={us_seed:.0f}|gemv_fixed_us={us_fixed:.0f}|"
                    f"auto_blocks={'x'.join(map(str, tuned))}|"
                    f"speedup={rec['speedup_vs_seed']:.2f}|"
                    f"model_us={cost.time_s * 1e6:.2f}|"
                    f"hbm_kb={cost.hbm_bytes / 1e3:.0f}")
            else:
                us = _time(lambda: ops.gptq_linear(
                    ql, x, strategy=strat, use_pallas=True,
                    block_sizes=SEED_BLOCKS))
                rec["us"] = us
                lines.append(
                    f"kernel/{name}/{s},{us:.0f},"
                    f"model_us={cost.time_s * 1e6:.2f}|"
                    f"hbm_kb={cost.hbm_bytes / 1e3:.0f}")
            records.append(rec)
    _bench_paged_decode(lines, records)
    try:
        with open(JSON_PATH, "w") as f:
            json.dump(records, f, indent=1)
        lines.append(f"kernel/json,0,written={os.path.abspath(JSON_PATH)}")
    except OSError as e:
        lines.append(f"kernel/json,0,ERROR={e!r}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
