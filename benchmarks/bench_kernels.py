"""Kernel microbench: per-strategy interpret-mode wall time (harness check)
plus the modeled v5e bytes/time per strategy for the paper's canonical GEMM
shapes (decode GEMV and prefill GEMM)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gptq
from repro.core.opt_strategies import STRATEGIES
from repro.core.perf_model import gptq_matmul_cost
from repro.kernels import ops

SHAPES = [
    ("decode_gemv", 8, 1024, 1024, 128),
    ("prefill_gemm", 128, 1024, 512, 128),
]


def run():
    lines = []
    rng = np.random.default_rng(0)
    for name, m, k, n, g in SHAPES:
        w = jnp.asarray(rng.normal(0, 0.5, (k, n)).astype(np.float32))
        ql = gptq.gptq_quantize(w, None, gptq.GPTQConfig(group_size=g))
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        for s, strat in STRATEGIES.items():
            cost = gptq_matmul_cost(m, k, n, group_size=g, strategy=strat)
            fn = lambda: ops.gptq_linear(ql, x, strategy=strat,
                                         use_pallas=True,
                                         block_sizes=(8, 256, 256))
            fn()  # compile/warm
            t0 = time.time()
            reps = 3
            for _ in range(reps):
                jax.block_until_ready(fn())
            us = (time.time() - t0) / reps * 1e6
            lines.append(
                f"kernel/{name}/{s},{us:.0f},"
                f"model_us={cost.time_s * 1e6:.2f}|hbm_kb={cost.hbm_bytes / 1e3:.0f}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
