"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  figs 2-3  : bench_paper_figs  (throughput/latency per model x strategy)
  tables1-2 : bench_accuracy    (ppl fp16 vs GPTQ vs RTN; strategy agreement)
  kernels   : bench_kernels     (per-strategy micro costs + decode fast lane;
                                 writes BENCH_kernels.json for the perf
                                 trajectory across PRs)
  serving   : bench_serving     (request-level ttft/tpot/throughput
                                 percentiles, slot vs paged; writes
                                 BENCH_serving.json)
  roofline  : roofline_table    (dry-run derived roofline per cell)

``--sections kernels,roofline`` runs a subset (default: all).
``--trace-out trace.json`` is forwarded to sections that accept it (today:
serving — exports a Perfetto trace of the preemption overload run).
"""
import argparse
import inspect
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sections", default="all",
                    help="comma-separated subset of "
                         "kernels,paper_figs,accuracy,serving,roofline "
                         "(default all)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON from "
                         "sections that support tracing (serving)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from benchmarks import bench_kernels, bench_paper_figs, bench_accuracy, \
        bench_serving, roofline_table
    sections = [
        ("kernels", bench_kernels.run),
        ("paper_figs", bench_paper_figs.run),
        ("accuracy", bench_accuracy.run),
        ("serving", bench_serving.run),
        ("roofline", roofline_table.run),
    ]
    if args.sections != "all":
        wanted = {s.strip() for s in args.sections.split(",")}
        unknown = wanted - {name for name, _ in sections}
        if unknown:
            sys.exit(f"unknown sections: {sorted(unknown)}")
        sections = [(n, f) for n, f in sections if n in wanted]
    failed = 0
    for name, fn in sections:
        try:
            kwargs = {}
            if (args.trace_out
                    and "trace_out" in inspect.signature(fn).parameters):
                kwargs["trace_out"] = args.trace_out
            for line in fn(**kwargs):
                print(line, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name}/ERROR,0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
