"""Request-level serving benchmark (ISSUE 3 + ISSUE 4): ttft / tpot /
throughput percentiles for the slot vs paged cache layouts, measured through
the streaming request-lifecycle API (``Engine.generate`` over a
ShareGPT-like synthetic workload — the same statistics the paper's vLLM runs
sample), plus the KV-quant capacity experiment: paged bf16 vs int8 KV under
the *same page-pool byte budget*, recording the cache footprint, quant mode
and the peak in-flight batch each mode sustains, plus (ISSUE 5) the paged
prefill gather-vs-kernel comparison: ttft percentiles and the analytic peak
prefill transient (``prefill_ttft_s`` / ``prefill_peak_bytes``) with the
contiguous-gather prefill vs the fused chunked paged-prefill kernel, plus
(ISSUE 8) the speculative-decoding on/off comparison: the n-gram speculator
over a repetitive-suffix greedy workload, recording acceptance rate,
accepted tokens per verify step, tokens per engine step and the tok/s +
step-count ratios against plain decode (token-identical output required),
plus (ISSUE 10) the chunked-prefill fusion comparison: hi-priority TTFT and
decode throughput under long-prompt load with the token-budgeted fused step
on (``max_step_tokens`` set) vs off (unbudgeted whole-prompt chunks).

Interpret-mode wall-clock on CPU: the numbers validate the serving harness
and track the *relative* slot-vs-paged / bf16-vs-int8 trajectory across PRs,
not TPU performance.  Emits CSV lines through benchmarks/run.py and writes
the structured record to BENCH_serving.json at the repo root.

Observability (ISSUE 7): every percentile below is derived from the
engine's metrics-registry histograms (``Histogram.quantile`` over explicit
buckets) — the same numbers a Prometheus scrape of ``/metrics`` would
yield — instead of private per-request lists; each record also embeds the
registry ``snapshot()``.  ``run(trace_out=...)`` attaches a step-span
tracer to the preemption overload run and exports a Perfetto trace.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import OPT4GPTQ
from repro.core.quantize_model import quantize_params
from repro.data.pipeline import sharegpt_stream
from repro.models import build_model
from repro.models import layers as L
from repro.perf import memory_model as MM
from repro.serving.api import EngineConfig, FinishReason, QueueFullError
from repro.serving.clock import ManualClock
from repro.serving.engine import Engine
from repro.serving.kv_quant import KVQuantConfig, page_bytes
from repro.serving.spec_decode import SpecConfig
from repro.serving.tracing import Tracer

N_REQUESTS = 8
MAX_NEW = 6
# overload experiment (ISSUE 6): open-loop Poisson arrivals with a burst,
# driven on a ManualClock (STEP_DT simulated seconds per engine step) so the
# queueing/preemption dynamics — not CPU interpret speed — set the latencies
OVL_REQUESTS = 12
OVL_PROMPT_LEN = 20
OVL_MAX_NEW = 6
OVL_STEP_DT = 1.0          # simulated seconds consumed by one engine step
OVL_MEAN_IARRIVAL = 1.0    # Poisson mean inter-arrival (simulated s)
OVL_BURST = (4, 8)         # request index range arriving at 4x rate
OVL_NUM_PAGES = 4          # page pool sized for ~2 concurrent sequences
OVL_MAX_QUEUED = 6
OVL_QUEUE_TIMEOUT_S = 8.0
# capacity experiment: fixed-length prompts so every request needs the same
# page count, and a budget of 4 bf16 pages — int8 (payload/2 + scales) buys
# ~7 pages from the identical byte budget
CAP_PROMPT_LEN = 28
CAP_MAX_NEW = 4
CAP_PAGE_SIZE = 16
CAP_BUDGET_PAGES_BF16 = 4
# speculative decoding experiment (ISSUE 8): repetitive-suffix prompts and a
# long greedy horizon so the n-gram speculator's periodic extrapolation gets
# full-k drafts accepted; k=8 with page_size=16 keeps every verify span
# inside two pages
SPEC_REQUESTS = 2
SPEC_MAX_NEW = 96
SPEC_K = 8
# chunked-prefill fusion experiment (ISSUE 10): long low-priority prompts
# arriving under a stream of short high-priority requests, driven on a
# ManualClock whose per-step advance is proportional to the tokens the step
# processed (CP_S_PER_TOKEN simulated s/token + CP_STEP_OVERHEAD_S launch
# overhead) — so an unbudgeted whole-prompt prefill step stalls every other
# stream for its full prompt length, while the token-budgeted fused step
# bounds each stall at max_step_tokens.  Slots/pages are sized so nothing
# queues on capacity: the measured hi-priority TTFT gap is purely the
# prefill-stall policy.  Decode tok/s per simulated second checks fusion
# does not cost throughput.
CP_BUDGET = 32             # max_step_tokens with fusion on
CP_LONG_LEN = 96
CP_LONG_MAX_NEW = 8
CP_LONG_ARRIVALS = (0.0, 10.0)
CP_SHORT_LEN = 8
CP_SHORT_MAX_NEW = 4
CP_N_SHORT = 6             # hi-prio shorts, one every 2.5 simulated s
CP_S_PER_TOKEN = 0.25
CP_STEP_OVERHEAD_S = 0.25
# tensor-parallel scaling (DESIGN.md §17): greedy shared-prefix workload at
# tp in {1,2,4} on a CPU-simulated 8-device mesh — run in a subprocess so
# the host-platform device-count flag applies regardless of how the parent
# bench process initialized jax
TP_DEGREES = (1, 2, 4)
TP_DEVICES = 8
TP_MAX_NEW = 4
TP_PREFIX_LEN = 20
JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_serving.json")


def _hist_pct(h) -> dict:
    """p50/p95/p99 estimated from histogram buckets — what
    ``histogram_quantile`` over a /metrics scrape computes (``h`` is a
    ``Family`` aggregate or one labeled ``Histogram`` child)."""
    return {p: round(h.quantile(q), 6)
            for p, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))}


def _run_engine(model, params, conf, prompts, max_new):
    eng = Engine(model, params, conf)
    t0 = time.time()
    outs = eng.generate(prompts, max_new_tokens=max_new, ignore_eos=True)
    dt = time.time() - t0
    toks = sum(len(o.output) for o in outs)
    m = eng.metrics
    rec = {
        "requests": len(outs), "tokens": toks, "wall_s": dt,
        "tok_per_s_interpret": toks / dt if dt else 0.0,
        # emitted tokens per engine step — batch concurrency for plain
        # decode, higher when a speculative verify step lands multiple
        # tokens per row (ISSUE 8); tpot_s below is already per *emitted
        # token* so the two never conflate
        "tokens_per_step": toks / max(1, eng.stats.steps),
        "steps": eng.stats.steps,
        "ttft_s": _hist_pct(m.ttft),
        "tpot_s": _hist_pct(m.tpot),
        "latency_s": _hist_pct(m.request_latency),
        "queue_wait_s": _hist_pct(m.queue_wait),
        "peak_active": eng.stats.peak_active,
        "finish_reasons": sorted({o.finish_reason.value for o in outs}),
        "metrics": m.registry.snapshot(),
    }
    return eng, outs, rec


def _cache_bytes(cfg, eng, conf) -> int:
    if eng.layout == "paged":
        return MM.paged_cache_bytes(cfg, eng.pc.num_pages, eng.pc.page_size,
                                    dtype=eng.cache_dtype,
                                    kv_quant=eng.kv_quant)
    return MM.slot_cache_bytes(cfg, conf.batch_slots, conf.max_len,
                               dtype=eng.cache_dtype, kv_quant=eng.kv_quant)


def _overload_run(cfg, model, params, kern, *, preemption: bool,
                  tracer: Tracer | None = None) -> dict:
    """Open-loop overload: requests arrive on a Poisson process (with a 4x
    burst window) in *simulated* time — the engine clock advances OVL_STEP_DT
    per step regardless of interpret-mode wall time, so TTFT percentiles
    measure queueing + preemption policy, reproducibly.  Percentiles come
    from the registry histograms (the ttft family is labeled by priority
    class, so the hi-priority split is one child read)."""
    rng = np.random.default_rng(11)
    gaps = rng.exponential(OVL_MEAN_IARRIVAL, size=OVL_REQUESTS)
    gaps[OVL_BURST[0]:OVL_BURST[1]] /= 4.0          # burst window
    arrivals = np.cumsum(gaps)
    prompts = [rng.integers(2, cfg.vocab_size, size=OVL_PROMPT_LEN).tolist()
               for _ in range(OVL_REQUESTS)]
    prios = [1 if i % 4 == 3 else 0 for i in range(OVL_REQUESTS)]

    clk = ManualClock(0.0)
    conf = EngineConfig(batch_slots=4, max_len=128, kernels=kern, eos_id=-1,
                        cache="paged", page_size=16,
                        num_pages=OVL_NUM_PAGES, clock=clk,
                        max_queued=OVL_MAX_QUEUED,
                        default_queue_timeout_s=OVL_QUEUE_TIMEOUT_S,
                        preemption=preemption, tracer=tracer)
    eng = Engine(model, params, conf)
    outs, nxt, steps = [], 0, 0
    while (nxt < OVL_REQUESTS or not eng.sched.idle) and steps < 500:
        while nxt < OVL_REQUESTS and arrivals[nxt] <= clk.now():
            try:
                eng.submit(prompts[nxt], max_new_tokens=OVL_MAX_NEW,
                           ignore_eos=True, priority=prios[nxt])
            except QueueFullError:
                pass                      # counted in stats.rejected_submits
            nxt += 1
        outs.extend(eng.step())
        eng._events.clear()
        clk.advance(OVL_STEP_DT)
        steps += 1
    if tracer is not None:
        tracer.flush_open(clk.now())
    served = [o for o in outs if o.finish_reason is not FinishReason.SHED]
    m = eng.metrics
    # hi-priority ttft: the priority="1" histogram child (fall back to the
    # aggregate when no hi request was ever served, as the list path did)
    hi_h = m.ttft.labels(priority="1")
    s = eng.stats
    return {
        "section": "overload", "layout": "paged",
        "preemption": preemption, "requests": OVL_REQUESTS,
        "mean_interarrival_s": OVL_MEAN_IARRIVAL, "step_dt_s": OVL_STEP_DT,
        "steps": steps,
        "finished": len(served), "shed": s.shed_requests,
        "rejected_submits": s.rejected_submits,
        "deferred_admissions": s.deferred_admissions,
        "preemptions": s.preemptions,
        "offloaded_pages": s.offloaded_pages,
        "offloaded_bytes": s.offloaded_bytes,
        "restored_pages": s.restored_pages,
        "ttft_s": _hist_pct(m.ttft),
        "ttft_hi_s": _hist_pct(hi_h if hi_h.count else m.ttft),
        "latency_s": _hist_pct(m.request_latency),
        "queue_wait_s": _hist_pct(m.queue_wait),
        "metrics": m.registry.snapshot(),
    }


def _chunked_prefill_run(cfg, model, params, kern, *,
                         budget: int | None) -> tuple[list, dict]:
    """One fusion-on/off run of the long-prefill-under-decode workload.
    Simulated time advances ``CP_STEP_OVERHEAD_S + 1s/token`` per step, so
    TTFT percentiles measure scheduling policy (how long a long prompt's
    prefill can stall the step), not CPU interpret speed."""
    rng = np.random.default_rng(13)
    work = [(t, rng.integers(2, cfg.vocab_size, size=CP_LONG_LEN).tolist(),
             0, CP_LONG_MAX_NEW) for t in CP_LONG_ARRIVALS]
    work += [(2.5 * (i + 1),
              rng.integers(2, cfg.vocab_size, size=CP_SHORT_LEN).tolist(),
              1, CP_SHORT_MAX_NEW) for i in range(CP_N_SHORT)]
    work.sort(key=lambda w: w[0])

    clk = ManualClock(0.0)
    conf = EngineConfig(batch_slots=8, max_len=160, kernels=kern, eos_id=-1,
                        cache="paged", page_size=16, num_pages=48, clock=clk,
                        max_step_tokens=budget)
    eng = Engine(model, params, conf)
    outs, nxt, steps = [], 0, 0
    while (nxt < len(work) or not eng.sched.idle) and steps < 500:
        while nxt < len(work) and work[nxt][0] <= clk.now():
            _, prompt, prio, max_new = work[nxt]
            eng.submit(prompt, max_new_tokens=max_new, ignore_eos=True,
                       priority=prio)
            nxt += 1
        # bill the step's token cost *before* running it, so first tokens
        # are stamped at the step's end, not its start: admit now (so the
        # plan is final — ``step`` finds nothing new to admit), read the
        # pure chunk plan, and advance the clock by the tokens it will
        # process (each decode row emits exactly one token without
        # speculation).
        eng._admit(outs)
        plan = eng.sched.plan_chunks(budget)
        n_decode = sum(not a.pending_prefill
                       for a in eng.sched.active.values())
        clk.advance(CP_STEP_OVERHEAD_S + CP_S_PER_TOKEN *
                    (n_decode + sum(plan.values())))
        outs.extend(eng.step())
        eng._events.clear()
        steps += 1
    m, s = eng.metrics, eng.stats
    hi_h = m.ttft.labels(priority="1")
    rec = {
        "section": "chunked_prefill", "layout": "paged",
        "max_step_tokens": budget, "requests": len(work), "steps": steps,
        "sim_s": clk.now(), "tokens": s.tokens_generated,
        "prefill_tokens": s.prefill_tokens,
        "decode_tok_per_sim_s": s.tokens_generated / max(clk.now(), 1e-9),
        "ttft_s": _hist_pct(m.ttft),
        "ttft_hi_s": _hist_pct(hi_h if hi_h.count else m.ttft),
        "latency_s": _hist_pct(m.request_latency),
        "queue_wait_s": _hist_pct(m.queue_wait),
        "metrics": m.registry.snapshot(),
    }
    return outs, rec


def _tp_child():
    """TP-scaling subprocess entry: runs the greedy shared-prefix workload
    through one engine per tp degree and prints the record list as JSON on
    stdout.  Needs ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    exported before jax initializes (the parent sets it)."""
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    kern = L.KernelConfig(strategy=OPT4GPTQ, use_pallas=True,
                          block_sizes=(8, 64, 64))
    prefix = list(range(1, TP_PREFIX_LEN + 1))
    prompts = [prefix + [100 + i] for i in range(N_REQUESTS)]
    out, base = [], None
    for tp in TP_DEGREES:
        conf = EngineConfig(batch_slots=4, max_len=96, kernels=kern,
                            eos_id=-1, cache="paged", page_size=16,
                            mesh_shape=(tp,) if tp > 1 else None)
        eng, outs, rec = _run_engine(model, qparams, conf, prompts,
                                     TP_MAX_NEW)
        rec = {"section": "tp_scaling", "layout": "paged",
               "kv_quant": "fp32", "tp": tp,
               "devices": len(jax.devices()),
               "num_pages": eng.pc.num_pages,
               "per_device_pool_bytes": MM.paged_cache_device_bytes(
                   cfg, eng.pc.num_pages, eng.pc.page_size,
                   dtype=eng.cache_dtype, kv_quant=eng.kv_quant, tp=tp),
               "prefix_hit_pages": eng.stats.prefix_hit_pages,
               "prefix_hit_tokens": eng.stats.prefix_hit_tokens, **rec}
        if tp == 1:
            base = outs
        else:
            rec["greedy_tokens_match_tp1"] = (
                [o.output for o in outs] == [o.output for o in base])
        out.append(rec)
    json.dump(out, sys.stdout)


def _tp_scaling_records() -> list[dict]:
    """Run ``_tp_child`` in a subprocess with an 8-way host-device CPU mesh
    and return its records (empty list + stderr passthrough on failure so a
    broken TP path fails the CI schema gate, not the whole bench)."""
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={TP_DEVICES}"
    prior = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prior:
        env["XLA_FLAGS"] = f"{prior} {flag}".strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(here, os.pardir, "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-c",
         "import bench_serving; bench_serving._tp_child()"],
        cwd=here, env=env, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-2000:])
        return []
    return json.loads(proc.stdout)


def run(trace_out: str | None = None):
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    kern = L.KernelConfig(strategy=OPT4GPTQ, use_pallas=True,
                          block_sizes=(8, 64, 64))
    reqs = sharegpt_stream(N_REQUESTS, vocab_size=cfg.vocab_size, seed=0,
                           mean_prompt=10, mean_output=MAX_NEW,
                           max_prompt=48)
    prompts = [r.prompt for r in reqs]

    lines, records = [], []
    for layout in ("slot", "paged"):
        conf = EngineConfig(batch_slots=4, max_len=128, kernels=kern,
                            eos_id=-1, cache=layout, page_size=16)
        eng, outs, rec = _run_engine(model, qparams, conf, prompts, MAX_NEW)
        rec = {"layout": layout, "kv_quant": "fp32",
               "cache_bytes": _cache_bytes(cfg, eng, conf), **rec}
        if layout == "paged":
            rec["prefix_hit_pages"] = eng.stats.prefix_hit_pages
            rec["prefix_hit_tokens"] = eng.stats.prefix_hit_tokens
        records.append(rec)
        ttft, tpot, lat = rec["ttft_s"], rec["tpot_s"], rec["latency_s"]
        lines.append(
            f"serving/{layout},{rec['wall_s'] * 1e6 / max(rec['tokens'], 1):.0f},"
            f"reqs={rec['requests']}|toks={rec['tokens']}|"
            f"tok_per_s={rec['tok_per_s_interpret']:.2f}|"
            f"ttft_p50_s={ttft['p50']:.3f}|ttft_p99_s={ttft['p99']:.3f}|"
            f"tpot_p50_s={tpot['p50']:.3f}|lat_p99_s={lat['p99']:.3f}")

    # ---- paged prefill: gather (ref) vs fused chunked kernel (ISSUE 5) ----
    # same paged workload twice; records ttft (prefill-dominated) and the
    # analytic peak prefill transient — the gather path's contiguous
    # per-layer KV copy vs the kernel's zero HBM materialization
    prefill_base = None
    for impl in ("gather", "kernel"):
        kern_i = L.KernelConfig(
            strategy=OPT4GPTQ, use_pallas=True, block_sizes=(8, 64, 64),
            paged_prefill_impl="ref" if impl == "gather" else "kernel")
        conf = EngineConfig(batch_slots=4, max_len=128, kernels=kern_i,
                            eos_id=-1, cache="paged", page_size=16)
        eng, outs, rec = _run_engine(model, qparams, conf, prompts, MAX_NEW)
        peak = MM.paged_prefill_peak_bytes(
            cfg, batch=1, max_pages=eng.pc.max_pages,
            page_size=eng.pc.page_size, dtype=eng.cache_dtype,
            kv_quant=eng.kv_quant, impl=impl)
        rec = {"section": "paged_prefill", "layout": "paged", "impl": impl,
               "kv_quant": "fp32", "prefill_ttft_s": rec["ttft_s"],
               "prefill_peak_bytes": peak,
               "cache_bytes": _cache_bytes(cfg, eng, conf), **rec}
        if impl == "gather":
            prefill_base = outs
        else:
            rec["greedy_tokens_match_gather"] = (
                [o.output for o in outs] == [o.output for o in prefill_base])
        records.append(rec)
        lines.append(
            f"serving/paged_prefill_{impl},"
            f"{rec['wall_s'] * 1e6 / max(rec['tokens'], 1):.0f},"
            f"prefill_peak_B={peak}|"
            f"ttft_p50_s={rec['prefill_ttft_s']['p50']:.3f}|"
            f"ttft_p99_s={rec['prefill_ttft_s']['p99']:.3f}|"
            f"tok_per_s={rec['tok_per_s_interpret']:.2f}")

    # ---- KV-quant capacity: same byte budget, bf16 vs int8 page pools ----
    budget = CAP_BUDGET_PAGES_BF16 * page_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, CAP_PAGE_SIZE,
        kv_quant=KVQuantConfig(dtype="bf16"))
    rng = np.random.default_rng(7)
    cap_prompts = [rng.integers(2, cfg.vocab_size,
                                size=CAP_PROMPT_LEN).tolist()
                   for _ in range(N_REQUESTS)]
    baseline = None
    for mode in ("bf16", "int8"):
        conf = EngineConfig(batch_slots=N_REQUESTS, max_len=128, kernels=kern,
                            eos_id=-1, cache="paged",
                            page_size=CAP_PAGE_SIZE, kv_quant=mode,
                            page_pool_bytes=budget)
        eng, outs, rec = _run_engine(model, qparams, conf, cap_prompts,
                                     CAP_MAX_NEW)
        rec = {"section": "kv_capacity", "layout": "paged", "kv_quant": mode,
               "page_pool_bytes": budget, "num_pages": eng.pc.num_pages,
               "cache_bytes": _cache_bytes(cfg, eng, conf), **rec}
        if mode == "bf16":
            baseline = outs
        else:
            rec["greedy_tokens_match_bf16"] = (
                [o.output for o in outs] == [o.output for o in baseline])
        records.append(rec)
        lines.append(
            f"serving/kv_capacity_{mode},"
            f"{rec['wall_s'] * 1e6 / max(rec['tokens'], 1):.0f},"
            f"budget_B={budget}|num_pages={rec['num_pages']}|"
            f"peak_active={rec['peak_active']}|"
            f"ttft_p50_s={rec['ttft_s']['p50']:.3f}|"
            f"tpot_p50_s={rec['tpot_s']['p50']:.3f}")

    # ---- overload: open-loop Poisson+burst arrivals, preemption on/off ----
    # every 4th request is high priority; with preemption enabled it evicts
    # a low-priority victim (offload to host) instead of queueing behind it,
    # which is exactly the p99-TTFT-for-priority-traffic trade the paper's
    # serving stack makes under saturation
    for preemption in (False, True):
        # trace the preemption run: its offload/restore/preempt spans are
        # the interesting Perfetto timeline (ManualClock -> deterministic)
        tracer = Tracer() if (trace_out and preemption) else None
        rec = _overload_run(cfg, model, qparams, kern, preemption=preemption,
                            tracer=tracer)
        records.append(rec)
        tag = "preempt" if preemption else "fifo"
        lines.append(
            f"serving/overload_{tag},{rec['steps']},"
            f"ttft_p99_s={rec['ttft_s']['p99']:.1f}|"
            f"hi_ttft_p99_s={rec['ttft_hi_s']['p99']:.1f}|"
            f"finished={rec['finished']}|shed={rec['shed']}|"
            f"rejected={rec['rejected_submits']}|"
            f"preemptions={rec['preemptions']}|"
            f"restored_pages={rec['restored_pages']}")
        if tracer is not None:
            tracer.export(trace_out)
            lines.append(f"serving/trace,0,written={os.path.abspath(trace_out)}"
                         f"|events={len(tracer.events)}")

    # ---- speculative decoding: n-gram spec on/off (ISSUE 8) ----
    # same repetitive-suffix greedy workload twice; the spec run must emit
    # token-identical output in fewer engine steps, with > 1 accepted draft
    # token per verify step.  Both runs score through the pure-JAX dequant
    # path (kernels=None): the verify pass batches K+1 positions through the
    # matmul lane while plain decode uses the single-token GEMV lane, and
    # under the Pallas GPTQ kernels those two accumulate in different orders
    # (~1e-7 on fp32 logits) — enough to flip near-tied argmaxes on the
    # smoke model, which would turn an exact-identity check into a flaky one.
    rng = np.random.default_rng(0)
    spec_prompts = []
    for _ in range(SPEC_REQUESTS):
        pat = rng.integers(2, cfg.vocab_size, size=4).tolist()
        spec_prompts.append(
            rng.integers(2, cfg.vocab_size, size=4).tolist() + pat * 3)
    spec_base = None
    for spec in (None, SpecConfig(method="ngram", k=SPEC_K)):
        conf = EngineConfig(batch_slots=SPEC_REQUESTS, max_len=256,
                            eos_id=-1, cache="paged", page_size=16,
                            num_pages=64, speculation=spec)
        eng, outs, rec = _run_engine(model, qparams, conf, spec_prompts,
                                     SPEC_MAX_NEW)
        s = eng.stats
        rec = {"section": "spec_decode", "layout": "paged",
               "speculate": "ngram" if spec else "off",
               "spec_k": SPEC_K if spec else 0,
               "spec_proposed": s.spec_proposed,
               "spec_accepted": s.spec_accepted,
               "spec_verify_steps": s.spec_verify_steps,
               "acceptance_rate": (s.spec_accepted / s.spec_proposed
                                   if s.spec_proposed else 0.0),
               "accepted_per_verify_step": (
                   s.spec_accepted / s.spec_verify_steps
                   if s.spec_verify_steps else 0.0), **rec}
        if spec is None:
            spec_base = (outs, rec)
        else:
            base_outs, base_rec = spec_base
            rec["greedy_tokens_match_plain"] = (
                [o.output for o in outs] == [o.output for o in base_outs])
            rec["tok_per_s_ratio_vs_plain"] = (
                rec["tok_per_s_interpret"]
                / max(base_rec["tok_per_s_interpret"], 1e-9))
            rec["step_ratio_vs_plain"] = (
                rec["steps"] / max(base_rec["steps"], 1))
        records.append(rec)
        tag = "ngram" if spec else "off"
        lines.append(
            f"serving/spec_{tag},"
            f"{rec['wall_s'] * 1e6 / max(rec['tokens'], 1):.0f},"
            f"steps={rec['steps']}|"
            f"tokens_per_step={rec['tokens_per_step']:.2f}|"
            f"acc_per_vstep={rec['accepted_per_verify_step']:.2f}|"
            f"acceptance_rate={rec['acceptance_rate']:.2f}|"
            f"tok_per_s={rec['tok_per_s_interpret']:.2f}")

    # ---- chunked prefill: fused token-budgeted step on/off (ISSUE 10) ----
    # fusion off = unbudgeted whole-prompt chunks (the old two-program
    # engine's stall profile); fusion on = max_step_tokens-budgeted chunks
    # interleaved with decode rows in one fused step.  The CI schema gate
    # checks hi-prio p99 TTFT (on <= off) and decode throughput (within 5%).
    cp_base = None
    for budget in (None, CP_BUDGET):
        outs, rec = _chunked_prefill_run(cfg, model, qparams, kern,
                                         budget=budget)
        if budget is None:
            cp_base = (outs, rec)
        else:
            base_outs, base_rec = cp_base
            key = lambda os_: sorted((o.rid, tuple(o.output)) for o in os_)
            rec["greedy_tokens_match_unbudgeted"] = key(outs) == key(base_outs)
            rec["decode_tok_per_s_ratio_vs_unbudgeted"] = (
                rec["decode_tok_per_sim_s"]
                / max(base_rec["decode_tok_per_sim_s"], 1e-9))
        records.append(rec)
        tag = "off" if budget is None else "on"
        lines.append(
            f"serving/chunked_prefill_{tag},{rec['steps']},"
            f"hi_ttft_p50_s={rec['ttft_hi_s']['p50']:.1f}|"
            f"hi_ttft_p99_s={rec['ttft_hi_s']['p99']:.1f}|"
            f"ttft_p99_s={rec['ttft_s']['p99']:.1f}|"
            f"decode_tok_per_sim_s={rec['decode_tok_per_sim_s']:.3f}|"
            f"sim_s={rec['sim_s']:.0f}")

    # ---- tensor-parallel scaling: tp 1/2/4 on an 8-way host mesh (§17) ----
    # token-identical greedy output is the acceptance bar; per-device pool
    # bytes shrink 1/tp at the same global page count (page ids are global,
    # each device holds its num_kv_heads/tp head-slice of every page)
    for rec in _tp_scaling_records():
        records.append(rec)
        match = ("" if rec["tp"] == 1 else
                 f"|match_tp1={rec['greedy_tokens_match_tp1']}")
        lines.append(
            f"serving/tp{rec['tp']},"
            f"{rec['wall_s'] * 1e6 / max(rec['tokens'], 1):.0f},"
            f"tok_per_s={rec['tok_per_s_interpret']:.2f}|"
            f"num_pages={rec['num_pages']}|"
            f"per_dev_pool_B={rec['per_device_pool_bytes']}|"
            f"prefix_hit_pages={rec['prefix_hit_pages']}{match}")

    try:
        with open(JSON_PATH, "w") as f:
            json.dump(records, f, indent=1)
        lines.append(f"serving/json,0,written={os.path.abspath(JSON_PATH)}")
    except OSError as e:
        lines.append(f"serving/json,0,ERROR={e!r}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
