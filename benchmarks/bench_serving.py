"""Request-level serving benchmark (ISSUE 3): ttft / tpot / throughput
percentiles for the slot vs paged cache layouts, measured through the
streaming request-lifecycle API (``Engine.generate`` over a ShareGPT-like
synthetic workload — the same statistics the paper's vLLM runs sample).

Interpret-mode wall-clock on CPU: the numbers validate the serving harness
and track the *relative* slot-vs-paged trajectory across PRs, not TPU
performance.  Emits CSV lines through benchmarks/run.py and writes the
structured record to BENCH_serving.json at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.gptq import GPTQConfig
from repro.core.opt_strategies import OPT4GPTQ
from repro.core.quantize_model import quantize_params
from repro.data.pipeline import sharegpt_stream
from repro.models import build_model
from repro.models import layers as L
from repro.serving.api import EngineConfig
from repro.serving.engine import Engine

N_REQUESTS = 8
MAX_NEW = 6
JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_serving.json")


def _pct(xs, unit=1.0) -> dict:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    return {p: float(np.percentile(xs, q)) * unit
            for p, q in (("p50", 50), ("p95", 95), ("p99", 99))}


def run():
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    qparams = quantize_params(params, None, GPTQConfig(group_size=32))
    kern = L.KernelConfig(strategy=OPT4GPTQ, use_pallas=True,
                          block_sizes=(8, 64, 64))
    reqs = sharegpt_stream(N_REQUESTS, vocab_size=cfg.vocab_size, seed=0,
                           mean_prompt=10, mean_output=MAX_NEW,
                           max_prompt=48)
    prompts = [r.prompt for r in reqs]

    lines, records = [], []
    for layout in ("slot", "paged"):
        eng = Engine(model, qparams, EngineConfig(
            batch_slots=4, max_len=128, kernels=kern, eos_id=-1,
            cache=layout, page_size=16))
        t0 = time.time()
        outs = eng.generate(prompts, max_new_tokens=MAX_NEW, ignore_eos=True)
        dt = time.time() - t0
        toks = sum(len(o.output) for o in outs)
        ttft = _pct([o.ttft for o in outs])
        tpot = _pct([o.tpot for o in outs if o.tpot > 0])
        lat = _pct([o.latency for o in outs])
        rec = {"layout": layout, "requests": len(outs), "tokens": toks,
               "wall_s": dt, "tok_per_s_interpret": toks / dt if dt else 0.0,
               "ttft_s": ttft, "tpot_s": tpot, "latency_s": lat,
               "finish_reasons": sorted({o.finish_reason.value
                                         for o in outs})}
        if layout == "paged":
            rec["prefix_hit_pages"] = eng.stats.prefix_hit_pages
            rec["prefix_hit_tokens"] = eng.stats.prefix_hit_tokens
        records.append(rec)
        lines.append(
            f"serving/{layout},{dt * 1e6 / max(toks, 1):.0f},"
            f"reqs={len(outs)}|toks={toks}|"
            f"tok_per_s={rec['tok_per_s_interpret']:.2f}|"
            f"ttft_p50_s={ttft['p50']:.3f}|ttft_p99_s={ttft['p99']:.3f}|"
            f"tpot_p50_s={tpot['p50']:.3f}|lat_p99_s={lat['p99']:.3f}")
    try:
        with open(JSON_PATH, "w") as f:
            json.dump(records, f, indent=1)
        lines.append(f"serving/json,0,written={os.path.abspath(JSON_PATH)}")
    except OSError as e:
        lines.append(f"serving/json,0,ERROR={e!r}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
