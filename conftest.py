"""Repo-level pytest wiring.

* Puts ``src/`` (the ``repro`` package) and ``tests/`` (shared helpers like
  ``_hypothesis_compat``) on ``sys.path`` so ``python -m pytest`` works with
  no PYTHONPATH ceremony.
* Registers the ``slow`` marker and deselects slow tests by default — the
  default tier stays under ~2 minutes.  Run everything with ``--runslow``
  (or select explicitly via ``-m slow``).
"""
from __future__ import annotations

import os
import sys

import pytest

_ROOT = os.path.dirname(os.path.abspath(__file__))
for _p in (os.path.join(_ROOT, "src"), os.path.join(_ROOT, "tests")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long model-smoke / system tests excluded from the default "
        "fast tier (enable with --runslow or -m slow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow") or config.getoption("-m"):
        return
    skip_slow = pytest.mark.skip(
        reason="slow tier: pass --runslow (or -m slow) to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
